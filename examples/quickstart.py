"""Quickstart: 10 rounds of wireless multimodal FL with JCSBA + one
LM-architecture forward pass through the public API.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.runtime import MFLExperiment
from repro.configs import get_config
from repro.launch import steps


def main():
    # --- the paper's system: decision-fusion MFL over a simulated cell ---
    exp = MFLExperiment(dataset="crema_d", scheduler="jcsba",
                        n_samples=400, seed=0)
    exp.run(10, verbose=True)
    print("final:", exp.final_metrics())

    # --- the model zoo: any assigned arch, reduced for CPU ---
    cfg = get_config("qwen3-4b").reduced()
    params = steps.init_fn(cfg)(jax.random.key(0))
    loss_fn = jax.jit(steps.make_loss_fn(cfg, attn_chunk=64))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 128)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 128)),
                                   jnp.int32)}
    print(f"{cfg.name} (reduced) loss:", float(loss_fn(params, batch)))


if __name__ == "__main__":
    main()
