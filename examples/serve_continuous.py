"""Continuous serving under live MFL training: a decode stream whose fusion
params hot-swap at every round boundary.

One process, one device chain: fused JCSBA rounds (``engine="fused"``)
advance the global fusion params; between rounds a ``ContinuousServer``
decodes a reduced-LM token stream whose sampling layer carries the fused
multimodal bias.  Each boundary swap is ONE donated device copy into the
serving buffers (``launch/parambuf``) — the decode jit cache stays warm, and
the run asserts zero post-warmup recompiles.

  PYTHONPATH=src python examples/serve_continuous.py --rounds 3
  PYTHONPATH=src python -m repro.launch.continuous --help   # full CLI
"""
from repro.launch.continuous import main

if __name__ == "__main__":
    main()
