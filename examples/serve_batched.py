"""Batched serving example: prefill + greedy decode of a reduced arch
through the same serve_step the multi-pod dry-run lowers for decode_32k.

  PYTHONPATH=src python examples/serve_batched.py --arch qwen3-0.6b
  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--reduced" not in sys.argv:
        sys.argv.append("--reduced")
    main()
