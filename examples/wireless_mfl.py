"""End-to-end driver (deliverable b): the paper's experiment — wireless MFL
training for a few hundred communication rounds, JCSBA vs. a baseline, on the
synthetic CREMA-D stand-in.  Saves curves + a comparison summary.

  PYTHONPATH=src python examples/wireless_mfl.py --rounds 120
"""
import argparse
import json
import os

import numpy as np

from repro.fl.runtime import MFLExperiment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--dataset", default="crema_d")
    ap.add_argument("--n-samples", type=int, default=800)
    ap.add_argument("--baseline", default="random")
    ap.add_argument("--dirichlet-alpha", type=float, default=0.0,
                    help="label-skew Dirichlet concentration (0 = IID "
                         "equal shards, the paper's setting; smaller = "
                         "stronger non-IID)")
    ap.add_argument("--engine", default="batched",
                    help="round engine spec '<loop>[:<backend>]': loop is "
                         "seq (per-client reference), batched (default, one "
                         "vmapped client stage per round) or fused (the "
                         "whole experiment as one lax.scan with device-"
                         "resident eval — every algorithm: jcsba/random/"
                         "round_robin/selection/dropout); the optional "
                         "backend picks the JCSBA solver for parity runs "
                         "(jax default, np = float64 mirror, seq = original "
                         "scalar path — host loops only)")
    ap.add_argument("--out", default="examples/out_wireless_mfl.json")
    args = ap.parse_args()

    eval_every = 4
    results = {}
    for algo in [args.baseline, "jcsba"]:
        fused = args.engine.partition(":")[0] == "fused"
        print(f"=== {algo}{' (fused)' if fused else ''} ===")
        exp = MFLExperiment(dataset=args.dataset, scheduler=algo,
                            n_samples=args.n_samples, seed=0,
                            dirichlet_alpha=args.dirichlet_alpha,
                            eval_every=eval_every, engine=args.engine)
        if fused:
            # one scan for the whole run: the device-resident eval samples
            # the same t % eval_every == 0 rounds as the host loop records
            exp.run_scanned(args.rounds)
        else:
            exp.run(args.rounds, verbose=False)
        fin = exp.final_metrics()
        curves = [(r.round, r.metrics.get("multimodal"), r.energy_total)
                  for r in exp.history if r.metrics]
        results[algo] = {"final": fin, "curve": curves}
        print(f"{algo}: multimodal={fin.get('multimodal', 0):.4f} "
              f"energy={fin.get('energy_total', 0):.3f}J "
              f"sched={fin.get('mean_sched_time_s', 0)*1e3:.1f}ms/round")

    mm_gain = (results["jcsba"]["final"].get("multimodal", 0)
               - results[args.baseline]["final"].get("multimodal", 0))
    print(f"\nJCSBA multimodal gain over {args.baseline}: {mm_gain*100:+.2f}% "
          f"(paper reports +4.06% over conventional algorithms)")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print("saved ->", args.out)


if __name__ == "__main__":
    main()
