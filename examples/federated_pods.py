"""Pods-as-clients: the paper's JCSBA scheduler driving LM-scale federated
training — the technique as a first-class feature of the distributed runtime
(DESIGN.md §4, hardware adaptation).

8 simulated "pods" (FL clients) each hold a shard of the token stream and a
reduced qwen3-0.6b replica.  Each round: the wireless layer simulates the
inter-site links (gains redrawn per round), JCSBA picks the pods and their
bandwidth under the latency/energy budget, the chosen pods take a local
AdamW step, and per-parameter federated averaging aggregates.  This is M=1
in the paper's notation — the unimodal degenerate case the bound still
covers (A2 only).

  PYTHONPATH=src python examples/federated_pods.py --rounds 12
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aggregation import unified_weights
from repro.core.convergence import BoundState
from repro.data.tokens import TokenStream
from repro.launch import steps
from repro.optim import adamw
from repro.wireless import cost as wcost
from repro.wireless.channel import Channel
from repro.wireless.lyapunov import EnergyQueues
from repro.wireless.params import WirelessParams
from repro.wireless.schedulers import ScheduleContext, JCSBAScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--pods", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config("qwen3-0.6b").reduced()
    K = args.pods
    rng = np.random.default_rng(0)

    # model upload size: a pod pushes its delta every round
    params = steps.init_fn(cfg)(jax.random.key(0))
    n_params = steps.param_count(params)
    model_bits = n_params * 16                       # bf16 on the wire

    # wireless layer: inter-site links; τ budget scaled to the model size
    P = WirelessParams(K=K, tau_max=2.0, B_max=200e6, E_add=5.0,
                       extra_gain_db=60.0)
    mods = [("lm",)] * K
    profile = {"lm": (float(model_bits), 5e5)}
    sizes = [args.batch * args.seq] * K
    cc = wcost.client_costs(sizes, mods, profile, P)
    ch = Channel(P, rng)
    queues = EnergyQueues(K)
    w = unified_weights(sizes, mods, ["lm"])
    bound = BoundState(K, ["lm"], mods, w, sizes)
    sched = JCSBAScheduler(rng, V=1.0)

    opt = adamw(3e-4)
    opt_state = opt.init(params)
    step_fn = jax.jit(steps.make_train_step(cfg, opt, attn_chunk=64))
    streams = [TokenStream(cfg.vocab_size, seed=k) for k in range(K)]

    for t in range(args.rounds):
        h = ch.draw()
        ctx = ScheduleContext(h=h, Q=queues.Q, cost=cc, params=P,
                              bound=bound, round_idx=t,
                              model_dist=np.zeros(K),
                              client_modalities=mods)
        dec = sched.schedule(ctx)
        part = np.flatnonzero(dec.a)
        tcom = wcost.com_latency(dec.B, h, cc.gamma_bits, P)
        ecom = wcost.com_energy(tcom, P)

        # each scheduled pod takes a local step from the global params;
        # aggregation = data-size-weighted average of the updated replicas
        grads_by_pod = []
        new_params_acc = None
        wsum = 0.0
        loss_round = []
        for k in part:
            b = streams[k].batch(args.batch, args.seq)
            batch = {kk: jnp.asarray(v) for kk, v in b.items()}
            newp, _, loss = step_fn(params, opt_state, batch)
            loss_round.append(float(loss))
            wk = sizes[k]
            wsum += wk
            contrib = jax.tree.map(lambda x: wk * x.astype(jnp.float32), newp)
            new_params_acc = contrib if new_params_acc is None else \
                jax.tree.map(jnp.add, new_params_acc, contrib)
            gk = jax.tree.map(lambda a_, b_: (a_ - b_), newp, params)
            grads_by_pod.append({"lm": gk})
        if new_params_acc is not None:
            params = jax.tree.map(
                lambda acc, old: (acc / wsum).astype(old.dtype),
                new_params_acc, params)
            agg = {"lm": jax.tree.map(
                lambda *g: sum(g) / len(g),
                *[gb["lm"] for gb in grads_by_pod])}
            full = [({"lm": gb["lm"]} if i < len(grads_by_pod) else None)
                    for i, gb in enumerate(grads_by_pod)]
            bound.update(full + [None] * (K - len(full)), agg)
        queues.step(dec.a.astype(float), ecom, cc.e_cmp, P.E_add)
        print(f"round {t:3d} pods={part.tolist()} "
              f"loss={np.mean(loss_round) if loss_round else float('nan'):.4f} "
              f"E={queues.spent.sum():.2f}J")
    print("done — JCSBA scheduled pods under link/energy budgets (M=1 case)")


if __name__ == "__main__":
    main()
