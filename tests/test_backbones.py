"""Contract tests for the model-adapter layer (fl/client.py) and the
transformer/SSD backbone adapters on the FL hot path.

Covers the adapter protocol end to end: backbone-parametrized fused-vs-host
equivalence (the tests/test_fused_round.py harness at tiny dims),
remat-on vs remat-off parity, Eq. 12 aggregation over backbone param
pytrees, the kernel-backed (Pallas) forward/backward parity, and the
dropout-stream bugfixes — the rate actually reaching the submodels, the
hash/eq value contract, and the PYTHONHASHSEED-independence of per-modality
dropout keys (regression: ``modal_logits`` used to fold in Python's
process-randomized ``hash(m)``, so two processes drew different masks).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core import fusion
from repro.fl.client import (BackboneAdapter, ModelAdapter, PaperModelAdapter,
                             make_adapter)
from repro.fl.runtime import MFLExperiment, parse_engine
from repro.models import paper_models as pm

from test_fused_round import CFG, _assert_equivalent

ENCODER_ARCHS = ("transformer", "ssd")


def _iemocap_batch(seed=0, B=4):
    rng = np.random.default_rng(seed)
    feats = {"audio": jnp.asarray(rng.standard_normal((B, 32, 11)),
                                  jnp.float32),
             "text": jnp.asarray(rng.standard_normal((B, 24, 100)),
                                 jnp.float32)}
    labels = jnp.asarray(rng.integers(0, 10, B))
    return feats, labels


# ---------------------------------------------------------------------------
# tentpole: backbone adapters drive the fused engine, equivalent to host
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ENCODER_ARCHS)
def test_fused_matches_batched_host_backbone(arch):
    host = MFLExperiment(dataset="iemocap", engine="batched", arch=arch,
                         **CFG)
    fus = MFLExperiment(dataset="iemocap", engine="fused", arch=arch, **CFG)
    host.run(4)
    fus.run(4)
    _assert_equivalent(host, fus)


@pytest.mark.parametrize("arch", ("lstm-cnn", "transformer"))
def test_remat_parity(arch):
    """engine="fused:remat" checkpoint-wraps each client's loss — same math,
    recomputed backward: trajectories must match the plain engine."""
    a = MFLExperiment(dataset="iemocap", engine="fused", arch=arch, **CFG)
    b = MFLExperiment(dataset="iemocap", engine="fused:remat", arch=arch,
                      **CFG)
    a.run_scanned(4)
    b.run_scanned(4)
    for x, y in zip(jax.tree.leaves(a._carry.params),
                    jax.tree.leaves(b._carry.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_kernel_path_parity():
    """use_kernels=True routes the mixers through the flash_attention /
    ssd_scan Pallas kernels; forward and (custom-VJP recomputed) gradients
    must match the XLA reference to fp32 tolerance."""
    feats, labels = _iemocap_batch()
    for arch in ENCODER_ARCHS:
        ax = make_adapter("iemocap", arch, use_kernels=False)
        ak = make_adapter("iemocap", arch, use_kernels=True)
        gp = ax.init_global(jax.random.key(0))

        def run(a):
            def f(p):
                lg = a.modal_logits(p, feats, dropout_rng=jax.random.key(3))
                total, _ = fusion.multimodal_loss(lg, labels, a.v_weights)
                return total
            return jax.value_and_grad(f)(gp)

        (lx, gx), (lk, gk) = run(ax), run(ak)
        assert float(lx) == pytest.approx(float(lk), abs=1e-5)
        for x, y in zip(jax.tree.leaves(gx), jax.tree.leaves(gk)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       atol=5e-6)


@pytest.mark.parametrize("arch", ENCODER_ARCHS)
def test_eq12_aggregation_over_backbone_pytrees(arch):
    """core.aggregation is architecture-agnostic: the stacked Eq. 12
    contraction over encoder param pytrees equals the manual per-leaf
    weighted sum, zero-weight rows dropping out exactly."""
    a = make_adapter("iemocap", arch)
    gp = a.init_global(jax.random.key(0))
    K = 3
    keys = jax.random.split(jax.random.key(1), K)
    stacked = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[a.init_global(k) for k in keys])
    w = {"audio": np.array([0.5, 0.5, 0.0]),
         "text": np.array([0.0, 0.25, 0.75])}
    out = agg.aggregate_stacked(gp, stacked, w)
    for m in gp:
        ref = jax.tree.map(
            lambda x: sum(w[m][k] * x[k] for k in range(K)), stacked[m])
        for x, y in zip(jax.tree.leaves(out[m]), jax.tree.leaves(ref)):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                       rtol=1e-5, atol=1e-6)
    # zero-sum weights leave the global submodel untouched
    out0 = agg.aggregate_stacked(gp, stacked,
                                 {"audio": np.zeros(K), "text": w["text"]})
    for x, y in zip(jax.tree.leaves(out0["audio"]),
                    jax.tree.leaves(gp["audio"])):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# satellite: dropout rate plumbing (PaperModelAdapter(dropout=) was dead)
# ---------------------------------------------------------------------------
def test_dropout_zero_equals_no_rng():
    feats, _ = _iemocap_batch()
    for adapter in (PaperModelAdapter("iemocap", dropout=0.0),
                    make_adapter("iemocap", "transformer", dropout=0.0)):
        gp = adapter.init_global(jax.random.key(0))
        with_rng = adapter.modal_logits(gp, feats,
                                        dropout_rng=jax.random.key(7))
        without = adapter.modal_logits(gp, feats, dropout_rng=None)
        for m in feats:
            np.testing.assert_allclose(np.asarray(with_rng[m]),
                                       np.asarray(without[m]), atol=1e-6)


def test_dropout_rate_changes_trajectories():
    """Regression: PaperModelAdapter(dropout=0.5) used to silently train at
    the hardcoded 0.1.  A non-default rate must change the local update."""
    feats, labels = _iemocap_batch()
    mods = tuple(sorted(feats))
    rng = jax.random.key(5)

    def one_step(rate):
        a = PaperModelAdapter("iemocap", dropout=rate)
        gp = a.init_global(jax.random.key(0))
        new, _, _, _ = a._update_fn(mods)(gp, feats, labels, rng)
        return new

    p1, p5 = one_step(0.1), one_step(0.5)
    diffs = [float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
             for x, y in zip(jax.tree.leaves(p1), jax.tree.leaves(p5))]
    assert max(diffs) > 1e-6


# ---------------------------------------------------------------------------
# satellite: hash/eq value contract
# ---------------------------------------------------------------------------
def test_adapter_hash_eq_contract():
    a = PaperModelAdapter("iemocap", eta=0.07, dropout=0.2)
    b = PaperModelAdapter("iemocap", eta=0.07, dropout=0.2)
    assert a == b and hash(a) == hash(b)
    assert a != PaperModelAdapter("iemocap", eta=0.07, dropout=0.3)
    assert a != PaperModelAdapter("crema_d", eta=0.07, dropout=0.2)
    # different classes never compare equal, whatever the shared fields
    assert PaperModelAdapter("iemocap") != make_adapter("iemocap",
                                                        "transformer")
    t1 = make_adapter("iemocap", "transformer")
    t2 = make_adapter("iemocap", "transformer")
    assert t1 == t2 and hash(t1) == hash(t2)
    assert t1 != make_adapter("iemocap", "ssd")
    assert t1 != make_adapter("iemocap", "transformer", use_kernels=True)
    assert t1 != make_adapter("iemocap", "transformer", remat=True)
    # equal-valued adapters share the lru_cache-d compiled steps
    assert a.cohort_step(("audio", "text")) is \
        b.cohort_step(("audio", "text"))


def test_make_adapter_routing():
    assert isinstance(make_adapter("iemocap"), PaperModelAdapter)
    assert isinstance(make_adapter("iemocap", "ssd"), BackboneAdapter)
    assert isinstance(make_adapter("crema_d", "transformer"), ModelAdapter)
    with pytest.raises(ValueError):
        make_adapter("iemocap", "resnet")


def test_parse_engine_tokens():
    assert parse_engine("fused")[0] == "fused"
    assert parse_engine("batched:np")[1] == "np"
    loop, solver, loss, remat, kern, canon = parse_engine("fused:pallas+remat")
    assert (loop, solver, loss, remat, kern) == \
        ("fused", "jax", "pallas", True, True)
    assert canon == "fused:pallas+remat"
    with pytest.raises(ValueError):
        parse_engine("fused:np+seq")
    with pytest.raises(ValueError):
        parse_engine("fused:warp")


# ---------------------------------------------------------------------------
# satellite: dropout keys independent of PYTHONHASHSEED (regression)
# ---------------------------------------------------------------------------
_HASHSEED_SCRIPT = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.models import paper_models as pm
rng = np.random.default_rng(0)
params = pm.init_iemocap_model(jax.random.key(0))
feats = {"audio": jnp.asarray(rng.standard_normal((4, 32, 11)), jnp.float32),
         "text": jnp.asarray(rng.standard_normal((4, 24, 100)), jnp.float32)}
out = pm.modal_logits(params, feats, dropout_rng=jax.random.key(11))
print(repr([np.asarray(out[m]).sum().item() for m in sorted(out)]))
"""


def test_modal_logits_independent_of_hashseed():
    """Dropout masks must be bit-identical across processes with different
    PYTHONHASHSEED values (the old ``hash(m)`` fold-in was randomized)."""
    def run(seed):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH="src" + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""))
        r = subprocess.run([sys.executable, "-c", _HASHSEED_SCRIPT],
                           capture_output=True, text=True, env=env,
                           cwd=os.path.dirname(os.path.dirname(
                               os.path.abspath(__file__))))
        assert r.returncode == 0, r.stderr
        return r.stdout.strip()

    assert run("0") == run("12345")


def test_modal_logits_subset_uses_global_modality_constant():
    """A modality-subset call (host seq path with modality dropout) must
    draw the same per-modality masks as the full-stack call — the fold-in
    constant is the *global* sorted-modality index, not the subset index."""
    feats, _ = _iemocap_batch()
    params = pm.init_iemocap_model(jax.random.key(0))
    rng = jax.random.key(9)
    full = pm.modal_logits(params, feats, dropout_rng=rng)
    only_text = pm.modal_logits({"text": params["text"]},
                                {"text": feats["text"]}, dropout_rng=rng)
    np.testing.assert_array_equal(np.asarray(full["text"]),
                                  np.asarray(only_text["text"]))
