"""Contract tests for the fused round engine (fl/fused_round.py).

With identical experiment seeds the fused ``round_step`` and the host-loop
reference (both ``engine="batched"`` and ``engine="seq"``) must produce the same
per-round participant sets, the same aggregated params to float32
reduction-order tolerance, and matching queue / ζ-δ tracker state over ≥5
rounds — the fused path's contract, parametrized over every traced scheduling
policy (jcsba / random / round_robin / selection / dropout — the host
wrappers and the fused engine drive the same ``wireless.policies`` cores, so
the harness locks the whole policy layer, not just JCSBA; for the dropout
baseline the per-round modality drop masks must match too).  Also locks the
zero-host-round-trips property (one trace for many rounds) and the
JSON-safety of records built from device arrays.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.fl.runtime import MFLExperiment, RoundRecord, jnp_or_np
from repro.wireless.policies import POLICY_NAMES

CFG = dict(n_samples=200, seed=3, eval_every=100)


def _fused_vs_host(dataset, host_engine, rounds=5, scheduler="jcsba"):
    host = MFLExperiment(dataset=dataset, engine=host_engine,
                         scheduler=scheduler, **CFG)
    fus = MFLExperiment(dataset=dataset, engine="fused", scheduler=scheduler,
                        **CFG)
    host.run(rounds)
    fus.run(rounds)
    return host, fus


def _assert_equivalent(host, fus):
    # identical rng-stream consumption ⇒ identical schedules round by round
    # (drop masks included — only the dropout policy's are ever non-empty)
    for ra, rb in zip(host.history, fus.history):
        assert ra.participants == rb.participants
        assert ra.failures == rb.failures
        assert ra.dropped == rb.dropped
    # Eq. 12 weights of the last round
    for m in host.all_mods:
        np.testing.assert_allclose(host.last_weights[m],
                                   fus.last_weights[m], atol=1e-6)
    # aggregated global params within float32 reduction-order tolerance
    for a, b in zip(jax.tree.leaves(host.global_params),
                    jax.tree.leaves(fus._carry.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    # Lyapunov queues + cumulative energy
    np.testing.assert_allclose(host.queues.Q,
                               np.asarray(fus._carry.Q, np.float64),
                               rtol=1e-5, atol=1e-9)
    np.testing.assert_allclose(host.queues.spent,
                               np.asarray(fus._carry.spent, np.float64),
                               rtol=1e-5, atol=1e-9)
    # Theorem-1 bound trackers
    for i, m in enumerate(fus._fused_engine.mods):
        assert host.bound.zeta[m] == pytest.approx(
            float(fus._carry.zeta[i]), abs=1e-3)
        np.testing.assert_allclose(host.bound.delta[m],
                                   np.asarray(fus._carry.delta[i]),
                                   atol=1e-4)
    np.testing.assert_allclose(host.model_dist,
                               np.asarray(fus._carry.model_dist), atol=1e-4)


@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_fused_matches_batched_host_loop_iemocap(policy):
    host, fus = _fused_vs_host("iemocap", "batched", scheduler=policy)
    _assert_equivalent(host, fus)


def test_fused_matches_sequential_host_loop_crema():
    host, fus = _fused_vs_host("crema_d", "seq")
    _assert_equivalent(host, fus)


@pytest.mark.parametrize("policy", ("jcsba", "round_robin"))
def test_fused_round_compiles_once(policy):
    """Zero host round-trips in steady state: many rounds, ONE trace of the
    fused program (the jit cache serves every subsequent round)."""
    fus = MFLExperiment(dataset="iemocap", engine="fused", scheduler=policy,
                        **CFG)
    fus.run(6)
    assert fus._fused_engine.trace_count == 1


def test_fused_requires_traced_policy():
    """The only schedulers without a traced core are JCSBA's np/seq parity
    backends — they must be rejected up front.  Dropout (formerly host-only)
    now runs fused; its acceptance is covered by the parametrized
    equivalence tests above."""
    with pytest.raises(ValueError):
        MFLExperiment(dataset="iemocap", scheduler="jcsba",
                      engine="fused:seq")
    with pytest.raises(ValueError):
        MFLExperiment(dataset="iemocap", scheduler="jcsba",
                      engine="fused:np")


def test_fused_dropout_records_drops():
    """The tentpole acceptance: engine="fused" with scheduler="dropout"
    runs scanned and the per-round drop masks reach the records (multimodal
    scheduled clients only, one modality at most)."""
    fus = MFLExperiment(dataset="iemocap", engine="fused",
                        scheduler="dropout",
                        scheduler_kwargs={"p_drop": 0.9}, **CFG)
    fus.run_scanned(6)
    multi = [k for k, ms in enumerate(fus.client_mods) if len(ms) > 1]
    seen = 0
    for rec in fus.history:
        sched = set(rec.participants) | set(rec.failures)
        dropped_clients = [k for ks in rec.dropped.values() for k in ks]
        assert len(dropped_clients) == len(set(dropped_clients))  # ≤1 each
        for m, ks in rec.dropped.items():
            assert m in fus.all_mods
            for k in ks:
                assert k in sched and k in multi
        seen += len(dropped_clients)
    assert seen > 0                     # p_drop=0.9 must actually drop


# ---------------------------------------------------------------------------
# record boundary: device arrays must never leak into JSON
# ---------------------------------------------------------------------------
def test_jnp_or_np_normalizes_device_values():
    import jax.numpy as jnp
    assert jnp_or_np(jnp.float32(1.5)) == 1.5
    assert jnp_or_np(jnp.arange(3)) == [0, 1, 2]
    assert jnp_or_np(np.float64(2.0)) == 2.0
    assert jnp_or_np({"a": jnp.int32(7), "b": [np.int64(1)]}) == \
        {"a": 7, "b": [1]}
    assert jnp_or_np("plain") == "plain"


def test_round_record_json_safe_under_jit():
    """Regression: RoundRecord fields produced by the fused (jitted) round
    used to be device arrays; json.dump of a history must work."""
    import jax.numpy as jnp
    fus = MFLExperiment(dataset="iemocap", engine="fused", **CFG)
    rec = fus.run_round()
    blob = json.dumps(dataclasses.asdict(rec))          # must not raise
    assert isinstance(rec.energy_total, float)
    assert all(isinstance(p, int) for p in rec.participants)
    assert "round" in blob
    # the constructor normalizes raw device arrays too
    rec2 = RoundRecord.make(jnp.int32(3), jnp.asarray([1, 2]), [],
                            jnp.float32(0.5), {"loss": jnp.float32(1.0)}, 0.0)
    json.dumps(dataclasses.asdict(rec2))
    assert rec2.participants == [1, 2] and rec2.metrics["loss"] == 1.0


def test_fused_checkpoint_manifest_json_safe(tmp_path):
    """save() mid-fused-experiment writes a manifest whose metadata came from
    the device carry — the JSON dump inside save_checkpoint must succeed and
    reload with float zeta values."""
    fus = MFLExperiment(dataset="iemocap", engine="fused", **CFG)
    fus.run(2)
    fus.save(str(tmp_path))
    manifest = json.load(open(str(tmp_path / "ckpt_00000002.json")))
    assert all(isinstance(v, float)
               for v in manifest["metadata"]["zeta"].values())
