"""FL production features: checkpoint/resume + non-IID partitioning."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import synthetic
from repro.data.partition import partition
from repro.fl.runtime import MFLExperiment


def test_checkpoint_resume_bitexact(tmp_path):
    """Save at round 4, run 4 more; a restored twin must produce the same
    global model (identical channel draws via the shared seed discipline)."""
    exp = MFLExperiment(dataset="crema_d", scheduler="round_robin",
                        n_samples=200, seed=7, eval_every=100)
    exp.run(4)
    exp.save(str(tmp_path))

    twin = MFLExperiment(dataset="crema_d", scheduler="round_robin",
                         n_samples=200, seed=7, eval_every=100)
    r = twin.restore(str(tmp_path))
    assert r == 4
    for m in exp.all_mods:
        for a, b in zip(np.asarray(exp.queues.Q), np.asarray(twin.queues.Q)):
            assert a == b
    # global params restored exactly
    import jax
    l1 = jax.tree.leaves(exp.global_params)
    l2 = jax.tree.leaves(twin.global_params)
    assert all(np.allclose(a, b) for a, b in zip(l1, l2))
    # restored experiment keeps running
    twin.run(2)
    assert twin._round == 6


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([0.1, 0.5, 5.0]), st.integers(0, 2 ** 31 - 1))
def test_property_dirichlet_partition_covers_dataset(alpha, seed):
    ds = synthetic.crema_like(seed=seed % 997, n=150)
    clients = partition(ds, 6, 0.3, seed=seed % 997, dirichlet_alpha=alpha)
    total = sum(c.size for c in clients)
    assert total == len(ds)
    assert all(c.size >= 1 for c in clients)
    all_idx = np.concatenate(
        [c.dataset.labels for c in clients])
    assert len(all_idx) == len(ds)


def test_dirichlet_skew_increases_with_small_alpha():
    ds = synthetic.crema_like(seed=0, n=600)

    def skew(alpha):
        clients = partition(ds, 6, 0.0 if False else 0.3, seed=0,
                            dirichlet_alpha=alpha)
        # mean per-client label-distribution TV distance from global
        gl = np.bincount(ds.labels, minlength=6) / len(ds)
        tvs = []
        for c in clients:
            p = np.bincount(c.dataset.labels, minlength=6) / max(c.size, 1)
            tvs.append(0.5 * np.abs(p - gl).sum())
        return float(np.mean(tvs))

    assert skew(0.1) > skew(10.0)


def test_noniid_fl_run():
    exp = MFLExperiment(dataset="crema_d", scheduler="jcsba", n_samples=200,
                        seed=0, eval_every=4)
    # swap in a non-IID partition
    from repro.data.partition import partition as part
    exp.clients = part(exp.train_ds, exp.params.K, 0.3, seed=0,
                       dirichlet_alpha=0.3)
    exp.client_mods = [c.modalities for c in exp.clients]
    exp.data_sizes = [c.size for c in exp.clients]
    exp.run(3)
    assert len(exp.history) == 3
