"""Sharded scenario sweeps: shard_map-vs-single-device parity.

``FusedRoundEngine.scan_v_grid`` must produce the same results whether the
scenario axis runs as one device's vmap or sharded over a
``("scenario",)`` mesh.  Device count is fixed at jax import, so the 4-device
case runs in a subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_
count=4`` (same pattern as tests/test_dryrun_mini.py).  The grid is
deliberately NOT divisible by the device count, so the pad-with-last-V /
slice-back path is exercised too.
"""
import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.fl.runtime import MFLExperiment
from repro.fl.fused_round import draw_round_xs
from repro.launch.mesh import make_sweep_mesh

exp = MFLExperiment(dataset="iemocap", scheduler="jcsba", K=6, n_samples=120,
                    seed=0, eval_every=10 ** 9, engine="fused")
eng = exp._get_fused_engine()
xs = draw_round_xs(exp, 3)
V = [0.01, 0.1, 1.0, 10.0, 3.0]            # 5 points on 4 devices -> padding

single = eng.scan_v_grid(V, exp._carry, xs, mesh=None)
mesh = make_sweep_mesh()
assert mesh is not None and int(mesh.devices.size) == 4, mesh
shard = eng.scan_v_grid(V, exp._carry, xs, mesh=mesh)

bit_exact = True
for a, b in zip(jax.tree.leaves(single), jax.tree.leaves(shard)):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, (a.shape, b.shape)
    if not np.array_equal(a, b):
        bit_exact = False
        np.testing.assert_allclose(a, b, rtol=2e-6, atol=1e-7)
# the V axis must actually differentiate scenarios (not a broadcast bug):
# the JCSBA objective J = V*bound + energy varies with V even when the
# argmin schedule does not
J = np.asarray(shard[1].J)                 # [n_V, R]
print(json.dumps({"ok": True, "devices": jax.device_count(),
                  "bit_exact": bit_exact, "n_V": int(J.shape[0]),
                  "distinct_J": len(set(np.round(J[:, 0], 8))) > 1}))
"""


def test_scan_v_grid_sharded_matches_single_device():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 4
    assert out["n_V"] == 5
    assert out["distinct_J"]


def test_sweep_mesh_single_device_is_none():
    """In the main test process (1 CPU device) the auto mesh must collapse to
    the single-device fallback instead of building a degenerate mesh."""
    from repro.launch.mesh import make_sweep_mesh
    assert make_sweep_mesh() is None
    assert make_sweep_mesh(1) is None
