"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned family runs one forward/train step on CPU — output shapes + no NaNs.
The FULL configs are exercised only by the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch import steps
from repro.optim import adamw


def _batch(cfg, rng, B=2, S=64):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    if cfg.arch_type == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, 8, cfg.frontend_dims[0])), jnp.float32)
    if cfg.arch_type == "audio":
        batch["src_embeds"] = jnp.asarray(
            rng.normal(size=(B, 32, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_train_step(name):
    cfg = ARCHS[name].reduced()
    rng = np.random.default_rng(0)
    params = steps.init_fn(cfg)(jax.random.key(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step = jax.jit(steps.make_train_step(cfg, opt, n_groups=1, attn_chunk=32))
    batch = _batch(cfg, rng)
    params2, opt_state, loss = step(params, opt_state, batch)
    assert np.isfinite(float(loss)), f"{name}: NaN loss"
    # params changed and kept shapes
    leaves1 = jax.tree.leaves(params)
    leaves2 = jax.tree.leaves(params2)
    assert all(a.shape == b.shape for a, b in zip(leaves1, leaves2))
    assert any(not np.allclose(a, b) for a, b in zip(leaves1, leaves2))
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in leaves2), f"{name}: NaN params"


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_prefill_shapes(name):
    cfg = ARCHS[name].reduced()
    rng = np.random.default_rng(0)
    params = steps.init_fn(cfg)(jax.random.key(0))
    fn = jax.jit(steps.make_prefill_step(cfg, n_groups=1, attn_chunk=32))
    batch = _batch(cfg, rng)
    batch.pop("labels")
    out = fn(params, batch)
    assert out.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(out, np.float32)).all()


def test_two_train_steps_reduce_loss_qwen3():
    """A tiny sanity-of-learning check on one dense family."""
    cfg = ARCHS["qwen3-0.6b"].reduced()
    rng = np.random.default_rng(0)
    params = steps.init_fn(cfg)(jax.random.key(0))
    opt = adamw(5e-3)
    opt_state = opt.init(params)
    step = jax.jit(steps.make_train_step(cfg, opt, n_groups=1, attn_chunk=32))
    batch = _batch(cfg, rng)                # same batch -> loss must drop
    losses = []
    for _ in range(5):
        params, opt_state, loss = step(params, opt_state, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
