"""Unit + checkpoint tests for the traced scheduling-policy layer
(``wireless.policies`` + the ``state()/load_state()`` scheduler API).

The fused-vs-host equivalence per policy lives in tests/test_fused_round.py;
here we lock the policy cores' decision semantics directly (cycling order,
subset sizes, per-group selection, equal-bandwidth split) and the explicit
checkpoint API: a mid-experiment save/restore must round-trip every policy's
state (JCSBA warm-start antibody, Round-Robin cursor) — the contract that
replaced the old ``getattr(scheduler, "_last_a")`` plumbing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fl.runtime import MFLExperiment
from repro.wireless.policies import (POLICY_NAMES, DropoutPolicy,
                                     RandomPolicy, RoundRobinPolicy,
                                     SelectionPolicy, make_policy,
                                     policy_step)

DATA = {"B_max": jnp.float32(10e6)}
DIST0 = jnp.zeros(8, jnp.float32)


def _step(policy, state, dist=None, seed=0):
    """Drive the jitted 6-tuple ``policy_step`` and return the classic
    4-tuple (tests that care about drop masks / cohort vectors unpack
    ``_step_full``)."""
    return _step_full(policy, state, dist, seed)[:4]


def _step_full(policy, state, dist=None, seed=0):
    state = {k: jnp.asarray(v) for k, v in state.items()}
    dist = DIST0[:policy.K] if dist is None else jnp.asarray(dist, jnp.float32)
    return policy_step(policy, state, DATA, dist, np.uint32(seed))


# ---------------------------------------------------------------------------
# traced cores
# ---------------------------------------------------------------------------
def test_random_policy_subset_and_equal_split():
    pol = RandomPolicy(K=8, n_sched=3)
    seen = set()
    for seed in range(6):
        _, a, B, J = _step(pol, pol.init_state(), seed=seed)
        a, B = np.asarray(a), np.asarray(B)
        assert a.sum() == 3
        np.testing.assert_allclose(B[a], 10e6 / 3, rtol=1e-6)
        assert (B[~a] == 0).all()
        assert np.isnan(float(J))
        seen.add(tuple(np.flatnonzero(a)))
    assert len(seen) > 1            # different seeds -> different subsets


def test_round_robin_policy_cycles_exactly():
    pol = RoundRobinPolicy(K=8, n_sched=3)
    state = pol.init_state()
    picked = []
    for seed in range(4):
        state, a, B, _ = _step(pol, state, seed=seed)
        picked.append(sorted(np.flatnonzero(np.asarray(a))))
    # same fixed order as the old host loop: 0-2, 3-5, 6-7+0, 1-3
    assert picked == [[0, 1, 2], [3, 4, 5], [0, 6, 7], [1, 2, 3]]
    assert int(np.asarray(state["next"])) == (4 * 3) % 8


def test_selection_policy_group_ratios_and_top_dist():
    mods = [("a", "b")] * 4 + [("a",)] * 2 + [("b",)] * 2
    pol = SelectionPolicy.from_modalities(8, mods, ratio=0.5)
    # groups: {a,b} size 4 -> 2 picks, {a} size 2 -> 1, {b} size 2 -> 1
    assert sorted(n for _, n in pol.group_picks) == [1, 1, 2]
    dist = np.array([0.1, 0.9, 0.5, 0.2, 0.3, 0.8, 0.0, 0.0])
    _, a, B, _ = _step(pol, pol.init_state(), dist=dist)
    a = np.asarray(a)
    # top-2 of group {a,b} by dist = clients 1, 2; top-1 of {a} = 5;
    # {b} all-zero dist -> stable tie-break to the lowest index, 6
    assert sorted(np.flatnonzero(a)) == [1, 2, 5, 6]
    np.testing.assert_allclose(np.asarray(B)[a], 10e6 / 4, rtol=1e-6)


def test_dropout_policy_drop_mask_semantics():
    """Scheduled multimodal clients drop at most one owned modality; the
    non-dropout step() is the drop-free projection of step_full()."""
    mods = [("a", "b")] * 4 + [("a",)] * 2 + [("b",)] * 2
    pol = DropoutPolicy.from_modalities(8, mods, n_sched=6, p_drop=1.0)
    assert pol.drop_mods == ("a", "b")
    owns = np.asarray(pol.owns)
    dropped_any = False
    for seed in range(5):
        state, a, B, J, drop, _ = _step_full(pol, {}, seed=seed)
        a, drop = np.asarray(a), np.asarray(drop)
        assert drop.shape == (2, 8)
        assert (drop <= owns).all()                 # only owned modalities
        assert (drop.sum(0) <= a).all()             # only scheduled clients
        # p_drop=1: every scheduled multimodal client drops exactly one
        multi = owns.sum(0) > 1
        np.testing.assert_array_equal(drop.sum(0), (a & multi).astype(int))
        dropped_any |= drop.any()
        # step() is step_full() minus the mask, on the same bits
        _, a2, B2, _ = _step(pol, {}, seed=seed)
        np.testing.assert_array_equal(a, np.asarray(a2))
        np.testing.assert_allclose(np.asarray(B), np.asarray(B2))
        assert np.isnan(float(J))
    assert dropped_any


def test_non_dropout_policies_emit_zero_row_drop_mask():
    for name in ("random", "round_robin", "selection"):
        pol = make_policy(name, 5, [("a",)] * 5)
        *_, drop, _idx = _step_full(pol, pol.init_state())
        assert drop.shape == (0, 5)


def test_cohort_idx_lists_scheduled_clients_first():
    """The sixth ``step_full`` output: a static-size, duplicate-free index
    vector whose leading ``a.sum()`` entries are exactly the scheduled
    clients in ascending order (stable argsort), padded with unscheduled
    indices that downstream ``a[idx]`` masks neutralize."""
    for name in ("random", "round_robin", "selection", "dropout"):
        pol = make_policy(name, 8, [("a", "b")] * 4 + [("a",)] * 4)
        dist = np.arange(8)[::-1].astype(np.float32)
        for seed in range(3):
            _, a, *_rest, idx = _step_full(pol, pol.init_state(), dist=dist,
                                           seed=seed)
            a, idx = np.asarray(a), np.asarray(idx)
            assert idx.shape == (pol.cohort_size,) and idx.dtype == np.int32
            assert len(set(idx.tolist())) == len(idx)          # no duplicates
            n = int(a.sum())
            assert n <= pol.cohort_size
            np.testing.assert_array_equal(np.sort(idx[:n]), idx[:n])
            np.testing.assert_array_equal(idx[:n], np.flatnonzero(a))
            assert not a[idx[n:]].any()                        # padding slots


def test_make_policy_factory_and_unknown_name():
    for name in POLICY_NAMES:
        pol = make_policy(name, 6, [("a",)] * 6)
        assert pol.K == 6 and pol.name == name
    with pytest.raises(ValueError):
        make_policy("no_such_policy", 6)


def test_policy_state_is_scan_compatible_pytree():
    """Policy states must flatten/unflatten cleanly and keep their structure
    across a step — lax.scan threads them through the fused carry.  (JCSBA's
    step needs the full solver context, so its structural check stops at the
    round-trip; the fused equivalence harness exercises its step.)"""
    for name in POLICY_NAMES:
        pol = make_policy(name, 5, [("a",)] * 5)
        state = {k: jnp.asarray(v) for k, v in pol.init_state().items()}
        leaves, treedef = jax.tree_util.tree_flatten(state)
        rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
        assert jax.tree_util.tree_structure(rebuilt) == treedef
        if name == "jcsba":
            continue
        new_state, a, B, _ = _step(pol, rebuilt)
        assert jax.tree_util.tree_structure(new_state) == treedef
        assert np.asarray(a).shape == (5,) and np.asarray(B).shape == (5,)


def test_bind_rebuilds_on_config_change_and_keeps_state_otherwise():
    """Regression: bind used to key the cached policy on K alone, so a
    same-K cohort with different modality ownership kept Selection's stale
    group structure.  Frozen-dataclass equality now detects the change —
    while an unchanged config must NOT reset evolving state (the Round-Robin
    cursor survives redundant rebinds)."""
    from repro.wireless.schedulers import (RoundRobinScheduler,
                                           SelectionScheduler)
    sel = SelectionScheduler(np.random.default_rng(0))
    sel.bind(4, [("a",), ("a",), ("b",), ("b",)])
    picks1 = sel.policy.group_picks
    sel.bind(4, [("a", "b")] * 4)                  # same K, new groups
    assert sel.policy.group_picks != picks1

    rr = RoundRobinScheduler(np.random.default_rng(0), n_sched=2)
    rr.bind(6)
    rr._state = {"next": np.asarray(4, np.int32)}  # mid-experiment cursor
    rr.bind(6)                                     # redundant rebind
    assert int(rr.state()["next"]) == 4


# ---------------------------------------------------------------------------
# checkpoint API: mid-experiment save/restore round-trip per policy
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("policy", POLICY_NAMES)
def test_policy_state_roundtrips_through_checkpoint(tmp_path, policy):
    cfg = dict(dataset="iemocap", scheduler=policy, n_samples=200, seed=7,
               eval_every=100, engine="fused")
    exp = MFLExperiment(**cfg)
    exp.run(3)
    exp.save(str(tmp_path))

    twin = MFLExperiment(**cfg)
    assert twin.restore(str(tmp_path)) == 3
    a_state, b_state = exp.scheduler.state(), twin.scheduler.state()
    assert sorted(a_state) == sorted(b_state)
    for k in a_state:
        assert a_state[k].dtype == b_state[k].dtype
        np.testing.assert_array_equal(a_state[k], b_state[k])
    # the rebuilt fused carry starts from the restored policy state
    for a, b in zip(jax.tree.leaves(exp._carry.policy),
                    jax.tree.leaves(twin._carry.policy)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    twin.run(1)                      # restored experiment keeps running
    assert twin._round == 4


def test_host_loop_policy_state_roundtrips_without_fused(tmp_path):
    """The API is engine-agnostic: a plain host-loop experiment checkpoints
    the Round-Robin cursor too (pre-policy versions silently dropped it)."""
    cfg = dict(dataset="iemocap", scheduler="round_robin", n_samples=200,
               seed=2, eval_every=100)
    exp = MFLExperiment(**cfg)
    exp.run(3)
    exp.save(str(tmp_path))
    twin = MFLExperiment(**cfg)
    twin.restore(str(tmp_path))
    assert int(twin.scheduler.state()["next"]) == \
        int(exp.scheduler.state()["next"])
