"""MoE dispatch invariants: routing conservation, capacity dropping,
load-balance aux, group independence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_apply


def _cfg(E=4, k=2, cf=1.25, shared=0):
    return ModelConfig(name="t", arch_type="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                       n_experts=E, top_k=k, expert_d_ff=48,
                       n_shared_experts=shared, capacity_factor=cf,
                       dtype="float32")


def test_output_shape_and_finite():
    cfg = _cfg()
    p = init_moe(jax.random.key(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 32)),
                    jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) > 0


def test_huge_capacity_equals_dense_expert_sum():
    """With capacity >> tokens, each token's output must equal the explicit
    gate-weighted sum of its top-k experts (no drops, no double counting)."""
    cfg = _cfg(E=4, k=2, cf=50.0)
    p = init_moe(jax.random.key(1), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
    y, _ = moe_apply(p, x, cfg)

    xf = x.reshape(-1, 32)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, 2)
    gates = gates / gates.sum(-1, keepdims=True)

    def expert(e, v):
        h = jax.nn.silu(v @ p["wg"][e]) * (v @ p["wu"][e])
        return h @ p["wd"][e]

    want = jnp.stack([
        sum(gates[t, j] * expert(int(idx[t, j]), xf[t]) for j in range(2))
        for t in range(8)])
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 32)),
                               np.asarray(want), rtol=2e-4, atol=2e-5)


def test_capacity_one_drops_overflow():
    """capacity_factor -> tiny: most tokens dropped => output magnitudes
    shrink but remain finite (GShard-style graceful degradation)."""
    cfg_lo = _cfg(E=4, k=2, cf=0.05)
    cfg_hi = _cfg(E=4, k=2, cf=50.0)
    p = init_moe(jax.random.key(2), cfg_hi)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(1, 32, 32)),
                    jnp.float32)
    y_lo, _ = moe_apply(p, x, cfg_lo)
    y_hi, _ = moe_apply(p, x, cfg_hi)
    assert np.isfinite(np.asarray(y_lo)).all()
    assert float(jnp.abs(y_lo).sum()) < float(jnp.abs(y_hi).sum())


def test_group_count_invariance_without_drops():
    """Dispatch groups are a sharding detail: with ample capacity the result
    must not depend on n_groups."""
    cfg = _cfg(E=4, k=2, cf=50.0)
    p = init_moe(jax.random.key(3), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 16, 32)),
                    jnp.float32)
    y1, _ = moe_apply(p, x, cfg, n_groups=1)
    y2, _ = moe_apply(p, x, cfg, n_groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-5)


def test_shared_expert_always_active():
    cfg = _cfg(shared=1)
    p = init_moe(jax.random.key(4), cfg)
    assert "shared" in p
    x = jnp.zeros((1, 4, 32))
    y, _ = moe_apply(p, x, cfg)
    assert y.shape == (1, 4, 32)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(1, 2), st.integers(0, 2 ** 31 - 1))
def test_property_aux_loss_lower_bound(E, k, seed):
    """Switch aux loss >= 1 at perfect balance; finite always."""
    cfg = _cfg(E=E, k=k)
    p = init_moe(jax.random.key(seed % 100), cfg)
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(1, 16, 32)),
                    jnp.float32)
    _, aux = moe_apply(p, x, cfg)
    assert np.isfinite(float(aux))
    assert float(aux) >= 0.99       # E * sum f_e P_e >= 1 by Cauchy-Schwarz
