"""Channel / cost / Lyapunov / immune-algorithm / scheduler tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.convergence import BoundState
from repro.core.aggregation import unified_weights
from repro.wireless import cost as wcost
from repro.wireless.channel import Channel, rate_ceiling, uplink_rate
from repro.wireless.immune import immune_search
from repro.wireless.lyapunov import EnergyQueues
from repro.wireless.params import MODALITY_PROFILES, WirelessParams
from repro.wireless.schedulers import (ScheduleContext, make_scheduler)

P = WirelessParams()


# ---------------------------------------------------------------------------
def test_rate_monotone_in_bandwidth():
    h = np.array([1e-5])
    B = np.linspace(1e5, 1e7, 50)
    r = uplink_rate(B, np.repeat(h, 50), P)
    assert np.all(np.diff(r) > 0)
    assert r[-1] < rate_ceiling(h, P)[0]


def test_channel_draw_positive_and_fading():
    ch = Channel(P, np.random.default_rng(0))
    h1, h2 = ch.draw(), ch.draw()
    assert np.all(h1 > 0) and np.all(h2 > 0)
    assert not np.allclose(h1, h2)          # small-scale fading varies


def test_cost_model_eq17_eq18():
    prof = MODALITY_PROFILES["crema_d"]
    cc = wcost.client_costs([100], [("audio", "image")], prof, P)
    phi = (2000 + P.beta0) + (8000 + P.beta0) - P.beta0
    assert cc.tau_cmp[0] == pytest.approx(100 * phi / P.f_cpu)
    assert cc.e_cmp[0] == pytest.approx(P.alpha * 100 * P.f_cpu ** 2 * phi)
    assert cc.gamma_bits[0] == 562400 + 557056


def test_energy_queue_dynamics():
    q = EnergyQueues(2)
    # spend more than E_add -> queue grows
    q.step(np.array([1.0, 0.0]), np.array([0.02, 0.0]), np.array([0.0, 0.0]),
           P.E_add)
    assert q.Q[0] == pytest.approx(0.01)
    assert q.Q[1] == 0.0
    # idle round replenishes
    q.step(np.zeros(2), np.zeros(2), np.zeros(2), P.E_add)
    assert q.Q[0] == pytest.approx(0.0)


# ---------------------------------------------------------------------------
def test_immune_beats_random_search_same_budget():
    rng = np.random.default_rng(0)
    K = 12
    w = rng.normal(size=K)

    def f(a):             # non-trivial quadratic with infeasible region
        a = np.asarray(a, float)
        if a.sum() > 6:
            return np.inf
        return float((w * a).sum() ** 2 - 2 * (w * a).sum())

    a_star, J_star = immune_search(f, K, np.random.default_rng(1))
    budget = 20 * 10 * 2
    rand = min(f(np.random.default_rng(2).integers(0, 2, K).astype(bool))
               for _ in range(budget))
    assert J_star <= rand + 1e-12


def test_immune_all_infeasible_returns_empty():
    a, J = immune_search(lambda a: np.inf if np.asarray(a).sum() else 0.0,
                         6, np.random.default_rng(0))
    assert a.sum() == 0


# ---------------------------------------------------------------------------
def _ctx(rng, K=6, dataset="crema_d"):
    prof = MODALITY_PROFILES[dataset]
    mods = [("audio", "image"), ("audio",), ("image",)] * (K // 3)
    sizes = [50] * K
    cc = wcost.client_costs(sizes, mods, prof, P)
    ch = Channel(WirelessParams(K=K), rng)
    w_bar = unified_weights(sizes, mods, ["audio", "image"])
    bound = BoundState(K, ["audio", "image"], mods, w_bar, sizes)
    return ScheduleContext(h=ch.draw(), Q=np.zeros(K), cost=cc,
                           params=WirelessParams(K=K), bound=bound,
                           round_idx=0, model_dist=np.zeros(K),
                           client_modalities=mods)


@pytest.mark.parametrize("name", ["random", "round_robin", "selection",
                                  "dropout", "jcsba"])
def test_scheduler_returns_valid_decision(name):
    rng = np.random.default_rng(0)
    ctx = _ctx(rng)
    sched = make_scheduler(name, rng)
    dec = sched.schedule(ctx)
    K = len(ctx.h)
    assert dec.a.shape == (K,) and dec.a.dtype == bool
    assert dec.B.shape == (K,)
    assert np.all(dec.B >= 0)
    assert dec.B.sum() <= ctx.params.B_max * (1 + 1e-6)
    assert np.all(dec.B[~dec.a] == 0)


def test_jcsba_bandwidth_respects_latency():
    rng = np.random.default_rng(1)
    ctx = _ctx(rng)
    dec = make_scheduler("jcsba", rng).schedule(ctx)
    part = np.flatnonzero(dec.a)
    if len(part):
        tcom = wcost.com_latency(dec.B[part], ctx.h[part],
                                 ctx.cost.gamma_bits[part], ctx.params)
        assert np.all(tcom + ctx.cost.tau_cmp[part]
                      <= ctx.params.tau_max * (1 + 1e-3))


def test_round_robin_cycles():
    rng = np.random.default_rng(0)
    sched = make_scheduler("round_robin", rng, n_sched=2)
    ctx = _ctx(rng)
    seen = set()
    for _ in range(3):
        dec = sched.schedule(ctx)
        seen.update(np.flatnonzero(dec.a).tolist())
    assert seen == set(range(6))


# ---------------------------------------------------------------------------
def test_bound_state_theorem1_limits():
    rng = np.random.default_rng(0)
    ctx = _ctx(rng)
    bs = ctx.bound
    K = 6
    # full participation -> A1 = A2 = 0 ("all clients participation makes the
    # whole term equal 0" — remark under Theorem 1)
    A1, A2 = bs.a1_a2(np.ones(K))
    assert A1 == 0.0 and A2 == pytest.approx(0.0, abs=1e-12)
    # empty participation -> A1 = sum of zeta^2, A2 = 0
    A1, A2 = bs.a1_a2(np.zeros(K))
    assert A1 == pytest.approx(sum(z ** 2 for z in bs.zeta.values()))
    assert A2 == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_bound_nonnegative(seed):
    rng = np.random.default_rng(seed)
    ctx = _ctx(rng)
    a = rng.integers(0, 2, 6).astype(float)
    A1, A2 = ctx.bound.a1_a2(a)
    assert A1 >= 0 and A2 >= 0
    assert ctx.bound.bound_term(a) >= 0
