"""Device-resident eval (fl/eval.py) vs the host ``ClientAdapter.evaluate``.

The fused round engine evaluates the freshly aggregated globals *inside* the
scanned program (``fl.eval.eval_metrics`` behind ``lax.cond``, flagged by
``RoundXs.eval_flag``); the host API jits the same function standalone.  On
the same params and test split the two must agree — multimodal accuracy,
per-modality accuracy and loss — including on an empty-cohort round (params
unchanged, eval still runs) and under an eval cadence > 1 inside one scan.
"""
import numpy as np
import pytest

from repro.fl.runtime import MFLExperiment
from repro.wireless.params import WirelessParams

CFG = dict(dataset="iemocap", n_samples=200, seed=3)


def _assert_metrics_match(dev: dict, host: dict, atol=1e-6):
    assert sorted(dev) == sorted(host)
    for k in host:
        assert dev[k] == pytest.approx(host[k], abs=atol), k


def test_device_eval_matches_host_adapter_stepwise():
    """Each fused run_round's record metrics come from the device eval of
    that round's aggregated params — bit-comparable to adapter.evaluate on
    the exported host mirror of the same params."""
    fus = MFLExperiment(engine="fused", scheduler="random", eval_every=1, **CFG)
    for _ in range(3):
        rec = fus.run_round()
        # export_carry already mirrored the carry params to global_params
        host = fus.adapter.evaluate(fus.global_params, fus.test_ds)
        _assert_metrics_match(rec.metrics, host)


def test_device_eval_empty_cohort_round():
    """A starved bandwidth budget makes every scheduled client miss the
    latency deadline — no participants, params unchanged — and the device
    eval must still emit the (unchanged) model's metrics."""
    params = WirelessParams(K=10, B_max=1e3)      # ~nothing to allocate
    fus = MFLExperiment(engine="fused", scheduler="random", eval_every=1,
                        params=params, **CFG)
    rec = fus.run_round()
    assert rec.participants == []                  # genuinely empty round
    host = fus.adapter.evaluate(fus.init_params, fus.test_ds)
    _assert_metrics_match(rec.metrics, host)


def test_device_eval_cadence_inside_scan():
    """One run_scanned with eval_every=2: metrics exist exactly on the grid
    rounds, NaN fillers never leak, and the final grid round's metrics match
    the host eval of the scan's final params."""
    fus = MFLExperiment(engine="fused", scheduler="random", eval_every=2, **CFG)
    fus.run_scanned(5)
    assert [bool(r.metrics) for r in fus.history] == \
        [True, False, True, False, True]
    for r in fus.history:
        assert all(np.isfinite(v) for v in r.metrics.values())
    host = fus.adapter.evaluate(fus._carry.params, fus.test_ds)
    _assert_metrics_match(fus.history[-1].metrics, host)


def test_scanned_curve_matches_stepwise_curve():
    """The scanned accuracy curve equals the stepwise fused curve point for
    point — eval inside lax.scan is the same program as eval in the single
    jitted step."""
    step = MFLExperiment(engine="fused", scheduler="round_robin", eval_every=2,
                        **CFG)
    scan = MFLExperiment(engine="fused", scheduler="round_robin", eval_every=2,
                        **CFG)
    step.run(4)
    scan.run_scanned(4)
    for ra, rb in zip(step.history, scan.history):
        assert sorted(ra.metrics) == sorted(rb.metrics)
        for k in ra.metrics:
            assert ra.metrics[k] == pytest.approx(rb.metrics[k], abs=1e-6)


def test_v_grid_sweep_emits_curves_without_host_eval(monkeypatch):
    """scan_v_grid's aux carries per-(V, round) metrics gated by eval_mask —
    the whole Fig.-4/Table-3 curve machinery with zero adapter.evaluate
    calls inside the scan."""
    import jax

    from repro.fl.fused_round import draw_round_xs

    exp = MFLExperiment(engine="fused", scheduler="random", eval_every=2, **CFG)
    eng = exp._get_fused_engine()
    xs = draw_round_xs(exp, 4, include_final=True)

    calls = []
    monkeypatch.setattr(exp.adapter, "evaluate",
                        lambda *a, **k: calls.append(1))
    carries, auxs = jax.block_until_ready(
        eng.scan_v_grid([0.1, 1.0], exp._carry, xs))
    assert not calls                               # zero host eval round-trips

    mask = np.asarray(auxs.eval_mask)              # [n_V, R]
    assert mask.shape == (2, 4)
    np.testing.assert_array_equal(mask[0], [True, False, True, True])
    mm = np.asarray(auxs.metrics["multimodal"])    # [n_V, R]
    assert np.isfinite(mm[mask]).all()             # real metrics on the grid
    assert np.isnan(mm[~mask]).all()               # NaN fillers off the grid
