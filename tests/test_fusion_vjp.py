"""Custom-VJP parity for the fused decision-fusion loss kernel.

Locks the blocked backward Pallas kernel (interpret mode on CPU CI) against
``jax.grad`` through the float64 reference: dlogits for every avail-mask
configuration, exact-zero gradients for masked modalities and zero-cotangent
(sample-mask-padded) rows, the fused ζ/δ partials (gsq/gdot), the dict
front-end's fwd+grad agreement with ``core.fusion.multimodal_loss``, the
Gram-form tracker refresh, and end-to-end ``engine="fused:pallas"`` vs
``engine="fused"`` equivalence over a multi-round scan.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import fusion as core_fusion
from repro.core.convergence import (grad_gram, tracker_update_cohort,
                                    tracker_update_gram)
from repro.kernels.fusion_loss import ops as kops
from repro.kernels.fusion_loss.ref import fusion_loss_ref_grads

RNG = np.random.default_rng(7)

# (M, T, V, bt, bv): divisible tiles, and tiles that divide neither T nor V
SHAPES = [
    (2, 16, 32, 8, 16),
    (3, 10, 13, 8, 8),
]
AVAIL_KINDS = ["full", "random", "empty_rows", "modality_out"]


def _avail(kind: str, M: int, T: int) -> jnp.ndarray:
    if kind == "full":
        a = np.ones((M, T))
    elif kind == "random":
        a = RNG.integers(0, 2, (M, T)).astype(float)
    elif kind == "empty_rows":
        a = RNG.integers(0, 2, (M, T)).astype(float)
        a[:, :3] = 0.0              # tokens with *no* modality available
    else:                           # modality_out: one head entirely absent
        a = np.ones((M, T))
        a[-1] = 0.0
    return jnp.asarray(a, jnp.float32)


def _case(M, T, V):
    logits = jnp.asarray(RNG.normal(size=(M, T, V)) * 3, jnp.float32)
    labels = jnp.asarray(RNG.integers(0, V, T), jnp.int32)
    cf = jnp.asarray(RNG.normal(size=T), jnp.float32)        # d_fused
    cm = jnp.asarray(RNG.normal(size=(M, T)), jnp.float32)   # d_modal
    return logits, labels, cf, cm


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,T,V,bt,bv", SHAPES)
@pytest.mark.parametrize("kind", AVAIL_KINDS)
def test_vjp_dlogits_vs_f64_ref(M, T, V, bt, bv, kind):
    """jax.grad through the kernel == float64 oracle for every mask shape."""
    logits, labels, cf, cm = _case(M, T, V)
    avail = _avail(kind, M, T)

    def scalar(lg):
        f, m = kops.fusion_loss(lg, labels, avail, block_t=bt, block_v=bv,
                                interpret=True)
        return (f * cf).sum() + (m * cm).sum()

    dl = jax.jit(jax.grad(scalar))(logits)
    with enable_x64():
        d_ref, _, _ = fusion_loss_ref_grads(logits, labels, avail, cf, cm)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(d_ref),
                               rtol=1e-4, atol=2e-5)
    # avail-masked (modality, token) slots must be *exactly* zero
    hole = np.asarray(avail)[..., None] == 0.0
    assert np.all(np.asarray(dl)[np.broadcast_to(hole, dl.shape)] == 0.0)


@pytest.mark.parametrize("M,T,V,bt,bv", SHAPES)
def test_vjp_zero_cotangent_rows_exactly_zero(M, T, V, bt, bv):
    """Sample-mask padding reaches the kernel as zero cotangents — rows with
    zero cotangent must produce bitwise-zero dlogits columns."""
    logits, labels, cf, cm = _case(M, T, V)
    pad = np.zeros(T, bool)
    pad[T // 2:] = True
    cf = cf * jnp.asarray(~pad, jnp.float32)
    cm = cm * jnp.asarray(~pad, jnp.float32)[None]

    def scalar(lg):
        f, m = kops.fusion_loss(lg, labels, block_t=bt, block_v=bv,
                                interpret=True)
        return (f * cf).sum() + (m * cm).sum()

    dl = np.asarray(jax.grad(scalar)(logits))
    assert np.all(dl[:, pad, :] == 0.0)
    assert np.any(dl[:, ~pad, :] != 0.0)


@pytest.mark.parametrize("M,T,V,bt,bv", SHAPES)
@pytest.mark.parametrize("kind", ["random", "empty_rows"])
def test_fused_partials_gsq_gdot(M, T, V, bt, bv, kind):
    """The backward's tile-accumulated ζ/δ partials match the f64 oracle."""
    logits, labels, cf, cm = _case(M, T, V)
    avail = _avail(kind, M, T)
    dl, gsq, gdot = kops.fusion_loss_grads(logits, labels, avail, cf, cm,
                                           block_t=bt, block_v=bv,
                                           interpret=True)
    with enable_x64():
        d_ref, gsq_ref, gdot_ref = fusion_loss_ref_grads(
            logits, labels, avail, cf, cm)
    np.testing.assert_allclose(np.asarray(dl), np.asarray(d_ref),
                               rtol=1e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(gsq), np.asarray(gsq_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gdot), np.asarray(gdot_ref),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
def test_front_end_fwd_and_grad_vs_core_fusion():
    """Dict front-end (broadcast head + scalar avail + sample mask) agrees
    with core.fusion.multimodal_loss in value and gradient."""
    B, S, V = 2, 6, 48
    lg = {"text": jnp.asarray(RNG.normal(size=(B, S, V)), jnp.float32),
          "vision": jnp.asarray(RNG.normal(size=(B, 1, V)), jnp.float32)}
    y = jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)
    smask = jnp.asarray(RNG.integers(0, 2, (B, S)), jnp.float32)
    vw = {"text": 4.0, "vision": 1.5}
    av = {"text": jnp.float32(1.0), "vision": jnp.float32(1.0)}

    def tot_k(lg):
        t, met = kops.fused_multimodal_loss(lg, y, vw, avail=av,
                                            sample_mask=smask, block_t=4,
                                            block_v=16, interpret=True)
        return t, met

    def tot_c(lg):
        t, met = core_fusion.multimodal_loss(lg, y, vw, avail=av,
                                             sample_mask=smask)
        return t, met

    (t_k, met_k) = tot_k(lg)
    (t_c, met_c) = tot_c(lg)
    np.testing.assert_allclose(float(t_k), float(t_c), rtol=1e-5)
    for key in ("F", "G", "G_text", "G_vision"):
        np.testing.assert_allclose(float(met_k[key]), float(met_c[key]),
                                   rtol=1e-5, atol=1e-6)
    g_k = jax.grad(lambda p: tot_k(p)[0])(lg)
    g_c = jax.grad(lambda p: tot_c(p)[0])(lg)
    for m in lg:
        assert g_k[m].shape == lg[m].shape
        np.testing.assert_allclose(np.asarray(g_k[m]), np.asarray(g_c[m]),
                                   rtol=1e-4, atol=1e-6)


def test_front_end_unavailable_modality_zero_grad():
    """A client without a modality (scalar avail 0) gets exactly zero
    gradient for that head under the cohort-style vmap."""
    B, S, V = 2, 4, 32
    lg = {"audio": jnp.asarray(RNG.normal(size=(B, S, V)), jnp.float32),
          "image": jnp.asarray(RNG.normal(size=(B, S, V)), jnp.float32)}
    y = jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)
    av = {"audio": jnp.float32(1.0), "image": jnp.float32(0.0)}

    g = jax.grad(lambda p: kops.fused_multimodal_loss(
        p, y, avail=av, block_t=4, block_v=16, interpret=True)[0])(lg)
    assert np.all(np.asarray(g["image"]) == 0.0)
    assert np.any(np.asarray(g["audio"]) != 0.0)


# ---------------------------------------------------------------------------
def test_tracker_gram_matches_cohort_diff():
    """Gram-form refresh == direct-difference refresh on the same cohort."""
    J, K = 4, 8
    tree = {"w": jnp.asarray(RNG.normal(size=(J, 5, 3)), jnp.float32),
            "b": jnp.asarray(RNG.normal(size=(J, 7)), jnp.float32)}
    mask_c = jnp.asarray([True, True, True, False])
    w_c = jnp.asarray([0.5, 0.3, 0.2, 0.0], jnp.float32)
    tree = jax.tree.map(lambda x: x * mask_c.reshape(
        (J,) + (1,) * (x.ndim - 1)), tree)   # padding slots carry zeros
    agg = jax.tree.map(lambda x: jnp.tensordot(w_c, x, axes=1), tree)
    idx = jnp.asarray([1, 3, 4, 6])
    has = jnp.ones(K, bool)
    z0 = jnp.float32(0.7)
    d0 = jnp.linspace(0.1, 0.9, K).astype(jnp.float32)

    za, da = tracker_update_cohort(z0, d0, tree, agg, mask_c, idx, has, 0.5)
    zb, db = tracker_update_gram(z0, d0, grad_gram(tree), w_c, mask_c, idx,
                                 has, 0.5)
    np.testing.assert_allclose(float(za), float(zb), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(da), np.asarray(db),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
def test_fused_round_engine_pallas_equivalence():
    """engine='fused:pallas' reproduces engine='fused' — params, energy
    queues and ζ/δ trackers — over a multi-round scan at f32 tolerance."""
    from repro.fl.runtime import MFLExperiment

    def run(engine):
        exp = MFLExperiment(dataset="crema_d", scheduler="jcsba", K=6,
                            n_samples=120, seed=3, engine=engine,
                            eval_every=10)
        for _ in range(2):
            exp.run_round()
        return exp

    a, b = run("fused"), run("fused:pallas")
    for x, y in zip(jax.tree.leaves(a.global_params),
                    jax.tree.leaves(b.global_params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
    np.testing.assert_allclose(a.queues.Q, b.queues.Q, atol=1e-4)
    for m in a.bound.zeta:
        assert abs(a.bound.zeta[m] - b.bound.zeta[m]) < 1e-3
        np.testing.assert_allclose(a.bound.delta[m], b.bound.delta[m],
                                   atol=1e-4)
