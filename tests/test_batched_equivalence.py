"""Batched round engine vs the sequential reference path.

Same seed ⇒ same np-rng stream ⇒ same schedules, same per-client dropout
keys; the batched path must then reproduce the sequential path's Eq. 12
weights exactly and the aggregated global params to float32 reduction-order
tolerance.  Also covers the stacked aggregation helpers in isolation and a
checkpoint save/restore roundtrip through the batched runtime.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.data import synthetic
from repro.data.partition import partition, stack_clients
from repro.fl.runtime import MFLExperiment


def _twin_run(dataset, scheduler, rounds=5, seed=3, n_samples=200, **kw):
    seq = MFLExperiment(dataset=dataset, scheduler=scheduler,
                        n_samples=n_samples, seed=seed, eval_every=100,
                        engine="seq", **kw)
    bat = MFLExperiment(dataset=dataset, scheduler=scheduler,
                        n_samples=n_samples, seed=seed, eval_every=100,
                        engine="batched", **kw)
    seq.run(rounds)
    bat.run(rounds)
    return seq, bat


def _assert_equivalent(seq, bat, atol=1e-5):
    # identical rng-stream consumption ⇒ identical schedules round by round
    for ra, rb in zip(seq.history, bat.history):
        assert ra.participants == rb.participants
        assert ra.failures == rb.failures
    # Eq. 12 weights of the last round identical
    for m in seq.all_mods:
        np.testing.assert_allclose(seq.last_weights[m], bat.last_weights[m],
                                   atol=1e-12)
    # aggregated global params equivalent within fp tolerance
    for a, b in zip(jax.tree.leaves(seq.global_params),
                    jax.tree.leaves(bat.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)


def test_round_robin_equivalence_crema():
    seq, bat = _twin_run("crema_d", "round_robin")
    _assert_equivalent(seq, bat)


def test_dropout_scheduler_equivalence_iemocap():
    """Modality dropout exercises the per-client upload-mask fallback."""
    seq, bat = _twin_run("iemocap", "dropout", rounds=4)
    _assert_equivalent(seq, bat)


def test_random_scheduler_equivalence_with_failures():
    """Equal-bandwidth random scheduling produces transmission failures —
    the upload mask must exclude them exactly like the sequential path."""
    seq, bat = _twin_run("crema_d", "random", rounds=4, n_samples=300,
                         scheduler_kwargs={"n_sched": 8})
    assert any(r.failures for r in seq.history)   # the regime we care about
    _assert_equivalent(seq, bat)


def test_trackers_and_model_dist_match():
    seq, bat = _twin_run("crema_d", "round_robin", rounds=4)
    for m in seq.all_mods:
        assert seq.bound.zeta[m] == pytest.approx(bat.bound.zeta[m], abs=1e-4)
        np.testing.assert_allclose(seq.bound.delta[m], bat.bound.delta[m],
                                   atol=1e-4)
    np.testing.assert_allclose(seq.model_dist, bat.model_dist, atol=1e-4)


# ---------------------------------------------------------------------------
# stacked helpers in isolation
# ---------------------------------------------------------------------------
def test_stacked_weights_match_weights_from_uploads():
    rng = np.random.default_rng(0)
    K, MODS = 7, ["audio", "image"]
    sizes = rng.integers(10, 100, K).tolist()
    uploads = []
    for _ in range(K):
        pick = rng.integers(0, 4)           # 0 = no upload at all
        uploads.append(None if pick == 0 else
                       {m: 1 for i, m in enumerate(MODS) if pick >> i & 1})
    mask = {m: np.array([u is not None and m in u for u in uploads])
            for m in MODS}
    w_ref = agg.weights_from_uploads(sizes, uploads, MODS)
    w_stk = agg.stacked_weights(sizes, mask)
    for m in MODS:
        np.testing.assert_allclose(w_stk[m], w_ref[m], atol=1e-15)


def test_aggregate_stacked_matches_loop():
    rng = np.random.default_rng(1)
    K, MODS = 5, ["audio", "image"]
    g = {m: {"w": jnp.zeros((4,)), "b": jnp.zeros(())} for m in MODS}
    stacked = {m: {"w": jnp.asarray(rng.normal(size=(K, 4)), jnp.float32),
                   "b": jnp.asarray(rng.normal(size=(K,)), jnp.float32)}
               for m in MODS}
    mask = {"audio": np.array([1, 1, 0, 1, 0], bool),
            "image": np.zeros(K, bool)}     # no image contributor
    w = agg.stacked_weights([10, 20, 30, 40, 50], mask)
    per_client = [{m: jax.tree.map(lambda x: x[k], stacked[m])
                   for m in MODS if mask[m][k]} or None for k in range(K)]
    out_ref = agg.aggregate(g, per_client, w)
    out_stk = agg.aggregate_stacked(g, stacked, w)
    for m in MODS:
        for a, b in zip(jax.tree.leaves(out_ref[m]),
                        jax.tree.leaves(out_stk[m])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
    # zero-contributor modality keeps the global unchanged
    np.testing.assert_allclose(np.asarray(out_stk["image"]["w"]), np.zeros(4))


def test_stack_clients_padding_and_masks():
    ds = synthetic.crema_like(seed=0, n=150)
    clients = partition(ds, 6, 0.3, seed=0, dirichlet_alpha=0.5)  # ragged
    sc = stack_clients(clients, sorted(ds.features.keys()))
    assert sc.K == 6 and sc.max_batch == max(c.size for c in clients)
    for k, c in enumerate(clients):
        assert sc.sample_mask[k].sum() == c.size
        np.testing.assert_array_equal(sc.labels[k, :c.size],
                                      c.dataset.labels)
        for m in sc.modalities:
            owns = m in c.modalities
            assert sc.has_modality[m][k] == owns
            if owns:
                np.testing.assert_array_equal(sc.features[m][k, :c.size],
                                              c.dataset.features[m])
            # padding (and non-owned blocks) stay zero
            assert not sc.features[m][k, c.size:].any()


def test_batched_equivalence_ragged_shards():
    """Dirichlet shards have genuinely ragged sizes — padding must not leak
    into the aggregate."""
    seq, bat = _twin_run("crema_d", "round_robin", rounds=3)
    for exp in (seq, bat):
        exp.clients = partition(exp.train_ds, exp.params.K, 0.3, seed=0,
                                dirichlet_alpha=0.5)
        exp.client_mods = [c.modalities for c in exp.clients]
        exp.data_sizes = [c.size for c in exp.clients]
    # re-run a few rounds on the swapped cohort (stack rebuilds lazily)
    seq.run(2)
    bat.run(2)
    _assert_equivalent(seq, bat)


# ---------------------------------------------------------------------------
# checkpointing through the batched runtime
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_batched(tmp_path):
    exp = MFLExperiment(dataset="crema_d", scheduler="round_robin",
                        n_samples=200, seed=7, eval_every=100)
    exp.run(3)
    exp.save(str(tmp_path))

    twin = MFLExperiment(dataset="crema_d", scheduler="round_robin",
                         n_samples=200, seed=7, eval_every=100)
    assert twin.restore(str(tmp_path)) == 3
    for a, b in zip(jax.tree.leaves(exp.global_params),
                    jax.tree.leaves(twin.global_params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(exp.queues.Q),
                                  np.asarray(twin.queues.Q))
    for m in exp.all_mods:
        np.testing.assert_allclose(exp.bound.delta[m], twin.bound.delta[m])
    np.testing.assert_allclose(exp.model_dist, twin.model_dist)
    # the restored experiment keeps training on the batched path
    twin.run(2)
    assert twin._round == 5
