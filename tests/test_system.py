"""End-to-end behaviour of the wireless MFL system (Algorithm 1)."""
import jax
import numpy as np
import pytest

from repro.fl.runtime import MFLExperiment


@pytest.fixture(scope="module")
def jcsba_exp():
    exp = MFLExperiment(dataset="crema_d", scheduler="jcsba", n_samples=300,
                        seed=0, eval_every=2)
    exp.run(8)
    return exp


def test_rounds_recorded(jcsba_exp):
    assert len(jcsba_exp.history) == 8
    assert any(r.metrics for r in jcsba_exp.history)


def test_energy_monotone_nondecreasing(jcsba_exp):
    e = [r.energy_total for r in jcsba_exp.history]
    assert all(b >= a for a, b in zip(e, e[1:]))


def test_jcsba_schedules_someone(jcsba_exp):
    assert any(r.participants for r in jcsba_exp.history)


def test_jcsba_no_transmission_failures(jcsba_exp):
    """JCSBA allocates bandwidth s.t. the latency constraint holds — unlike
    the equal-split baselines it must never produce a failed upload."""
    assert all(not r.failures for r in jcsba_exp.history)


def test_bound_trackers_update(jcsba_exp):
    bs = jcsba_exp.bound
    assert any(z != 1.0 for z in bs.zeta.values())


def test_loss_improves_over_training():
    exp = MFLExperiment(dataset="crema_d", scheduler="jcsba", n_samples=300,
                        seed=1, eval_every=1)
    exp.run(24)
    losses = [r.metrics["loss"] for r in exp.history if r.metrics]
    # compare trailing vs leading window means — single-round evals are noisy
    assert np.mean(losses[-4:]) < np.mean(losses[:4])


def test_modality_dropout_scheduler_runs():
    exp = MFLExperiment(dataset="iemocap", scheduler="dropout", n_samples=200,
                        seed=0, eval_every=4)
    exp.run(4)
    assert len(exp.history) == 4


def test_baselines_can_fail_transmission():
    """Equal-bandwidth baselines violate C4 sometimes — the runtime must
    record those as failures rather than silently aggregating."""
    exp = MFLExperiment(dataset="crema_d", scheduler="random", n_samples=300,
                        seed=0, eval_every=4,
                        scheduler_kwargs={"n_sched": 8})
    exp.run(6)
    n_fail = sum(len(r.failures) for r in exp.history)
    assert n_fail > 0


# ---------------------------------------------------------------------------
# fused engine: lax.scan invariance + carry checkpointing
# ---------------------------------------------------------------------------
def _fused_exp():
    return MFLExperiment(dataset="iemocap", scheduler="jcsba", n_samples=200,
                         seed=5, eval_every=100, engine="fused")


def test_run_scanned_matches_stepwise_bit_for_bit():
    """run_scanned(R) must equal R successive fused round_step calls exactly:
    the scan body and the per-round jit trace the same Python function on the
    same pregenerated randomness.  Exact equality is a CPU-backend contract —
    conftest pins JAX_PLATFORMS=cpu for the whole suite; if an XLA upgrade
    ever reorders the scan body's float reductions, relax this to a tight
    allclose rather than weakening the randomness/carry plumbing."""
    step = _fused_exp()
    scan = _fused_exp()
    step.run(5)
    scan.run_scanned(5)
    for a, b in zip(jax.tree.leaves(step._carry),
                    jax.tree.leaves(scan._carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for ra, rb in zip(step.history, scan.history):
        assert ra.participants == rb.participants
        assert ra.failures == rb.failures
        assert ra.energy_total == rb.energy_total


def test_fused_checkpoint_roundtrips_carry_mid_experiment(tmp_path):
    """save()/restore() must round-trip the fused carry — params, queues,
    ζ/δ trackers, warm-start antibody and model_dist — mid-experiment, and
    the restored experiment must keep scanning."""
    exp = _fused_exp()
    exp.run_scanned(3)
    exp.save(str(tmp_path))

    twin = _fused_exp()
    assert twin.restore(str(tmp_path)) == 3
    for a, b in zip(jax.tree.leaves(exp._carry),
                    jax.tree.leaves(twin._carry)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # warm start survives into the host-side policy state too
    np.testing.assert_array_equal(
        np.asarray(exp._carry.policy["warm_a"]),
        twin.scheduler.state()["warm_a"])
    twin.run_scanned(2)
    assert twin._round == 5 and len(twin.history) == 2
