"""Cohort-gather path: segment-sum aggregation ≡ dense masked Eq. 12, the
2-D ("scenario", "clients") mesh parity, and the unified engine= API.

The fused round's hot path gathers the scheduled cohort's J rows and runs
Eq. 12 / tracker updates on the [J] stack (fl/fused_round.py); the dense
masked implementations stay the reference.  Property tests here drive both
on random cohorts — including empty schedules and whole-population cohorts
— and demand agreement to f32 reduction-order tolerance (the cohort keeps
the dense path's ascending-client summation order, so weights/scatters are
exact and only the tensordot contractions pick up reduction-order noise).

The 2-D mesh subprocess test mirrors tests/test_sharded_sweep.py: 4 virtual
CPU devices as a 2×2 scenario×clients mesh, client store + per-client
randomness sharded, vs the single-device vmap.

The engine= API tests lock the deprecation surface: legacy
``batched=/solver=/fused=`` kwargs map onto the spec with a warning, as do
``draw_round_xs(eval_every=...)`` and pre-policy ``warm_a`` checkpoints.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.convergence import (tracker_update_cohort,
                                    tracker_update_masked)
from repro.wireless.policies import cohort_indices

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _random_case(rng, K=12, J=5, n_mods=2, leaf_shapes=((3,), (2, 4))):
    """Random dense round: params/stacks/upload masks + the cohort view."""
    mods = [f"m{i}" for i in range(n_mods)]
    a = np.zeros(K, bool)
    a[rng.choice(K, size=rng.integers(0, J + 1), replace=False)] = True
    idx = np.asarray(cohort_indices(jnp.asarray(a), J))
    D = rng.uniform(1.0, 9.0, K)
    has = {m: rng.random(K) < 0.8 for m in mods}
    upload = {m: a & has[m] & (rng.random(K) < 0.9) for m in mods}
    g = {m: {f"w{j}": rng.standard_normal((K,) + s).astype(np.float32)
             for j, s in enumerate(leaf_shapes)} for m in mods}
    glob = {m: {f"w{j}": rng.standard_normal(s).astype(np.float32)
                for j, s in enumerate(leaf_shapes)} for m in mods}
    # zero out non-upload rows like the masked BGD does (exact zeros)
    gz = {m: jax.tree.map(
        lambda x: jnp.asarray(x) * upload[m].reshape((K,) + (1,) * (x.ndim - 1)),
        g[m]) for m in mods}
    return mods, a, idx, D, has, upload, gz, glob


def _gather(tree, idx):
    return jax.tree.map(lambda x: jnp.asarray(x)[idx], tree)


@pytest.mark.parametrize("seed", range(4))
def test_cohort_aggregation_matches_dense_eq12(seed):
    rng = np.random.default_rng(seed)
    K, J = 12, 5
    mods, a, idx, D, has, upload, gz, glob = _random_case(rng, K, J)

    w_dense = agg.stacked_weights_traced(D, upload)
    new_dense = agg.aggregate_stacked_traced(glob, gz, w_dense)
    agg_dense = agg.aggregate_gradients_stacked_traced(gz, w_dense)

    upload_c = {m: jnp.asarray(upload[m])[idx] for m in mods}
    w_c = agg.stacked_weights_traced(jnp.asarray(D, jnp.float32)[idx],
                                     upload_c)
    gz_c = {m: _gather(gz[m], idx) for m in mods}
    new_cohort = agg.aggregate_stacked_traced(glob, gz_c, w_c)
    agg_cohort = agg.aggregate_gradients_stacked_traced(gz_c, w_c)
    w_scat = agg.cohort_weights_dense(w_c, jnp.asarray(idx), K)

    for m in mods:
        # the weight scatter is exact: duplicate-free indices, zero padding
        np.testing.assert_array_equal(np.asarray(w_dense[m]),
                                      np.asarray(w_scat[m]))
        for da, ca in zip(jax.tree.leaves(new_dense[m]),
                          jax.tree.leaves(new_cohort[m])):
            np.testing.assert_allclose(np.asarray(da), np.asarray(ca),
                                       atol=1e-6)
        for da, ca in zip(jax.tree.leaves(agg_dense[m]),
                          jax.tree.leaves(agg_cohort[m])):
            np.testing.assert_allclose(np.asarray(da), np.asarray(ca),
                                       atol=1e-6)


def test_cohort_aggregation_empty_and_full_cohort():
    rng = np.random.default_rng(99)
    K, J = 8, 8
    mods, a, idx, D, has, upload, gz, glob = _random_case(rng, K, J)

    # empty schedule: all-False uploads keep the globals bit-identical and
    # the weights all-zero, on both paths
    empty = {m: np.zeros(K, bool) for m in mods}
    idx0 = np.asarray(cohort_indices(jnp.zeros(K, bool), J))
    w_c = agg.stacked_weights_traced(jnp.asarray(D, jnp.float32)[idx0],
                                     {m: jnp.asarray(empty[m])[idx0]
                                      for m in mods})
    new_c = agg.aggregate_stacked_traced(glob, {m: _gather(gz[m], idx0)
                                                for m in mods}, w_c)
    for m in mods:
        assert float(jnp.abs(w_c[m]).sum()) == 0.0
        for ga, gb in zip(jax.tree.leaves(glob[m]),
                          jax.tree.leaves(new_c[m])):
            np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))

    # whole-population cohort (J = K): the gather is a permutation-free
    # identity, so cohort and dense weights agree exactly
    full = {m: np.asarray(has[m], bool) for m in mods}
    idx1 = np.asarray(cohort_indices(jnp.ones(K, bool), K))
    np.testing.assert_array_equal(idx1, np.arange(K))
    w_dense = agg.stacked_weights_traced(D, full)
    w_c = agg.stacked_weights_traced(jnp.asarray(D, jnp.float32)[idx1],
                                     {m: jnp.asarray(full[m])[idx1]
                                      for m in mods})
    for m in mods:
        np.testing.assert_array_equal(
            np.asarray(w_dense[m]),
            np.asarray(agg.cohort_weights_dense(w_c, jnp.asarray(idx1), K)[m]))


def test_cohort_indices_matches_stable_argsort_spec():
    """cohort_indices is implemented as an O(K log J) top-k over a ranking
    key; it must stay bit-identical to the stable-argsort specification
    (scheduled-first, ascending within each group) for every mask."""
    rng = np.random.default_rng(7)
    for _ in range(100):
        K = int(rng.integers(1, 40))
        J = int(rng.integers(1, K + 1))
        a = jnp.asarray(rng.random(K) < rng.random())
        np.testing.assert_array_equal(
            np.asarray(cohort_indices(a, J)),
            np.asarray(jnp.argsort(~a)[:J].astype(jnp.int32)))


def test_scatter_cohort_rows_is_exact_inverse_of_take():
    rng = np.random.default_rng(5)
    K, J = 10, 4
    idx = jnp.asarray(rng.choice(K, J, replace=False).astype(np.int32))
    vals = jnp.asarray(rng.standard_normal((J, 3)).astype(np.float32))
    dense = np.asarray(agg.scatter_cohort_rows(vals, idx, K))
    assert dense.shape == (K, 3)
    np.testing.assert_array_equal(dense[np.asarray(idx)], np.asarray(vals))
    others = np.setdiff1d(np.arange(K), np.asarray(idx))
    np.testing.assert_array_equal(dense[others], 0.0)


@pytest.mark.parametrize("seed", range(3))
def test_tracker_update_cohort_matches_masked(seed):
    rng = np.random.default_rng(seed + 40)
    K, J = 12, 5
    mods, a, idx, D, has, upload, gz, glob = _random_case(rng, K, J)
    m = mods[0]
    zeta0 = jnp.float32(rng.uniform(0.5, 2.0))
    delta0 = jnp.asarray(rng.uniform(0.1, 1.0, K).astype(np.float32))
    w = agg.stacked_weights_traced(D, upload)
    ag = agg.aggregate_gradients_stacked_traced(gz, w)[m]

    z_ref, d_ref = tracker_update_masked(
        zeta0, delta0, gz[m], ag, upload[m], has[m], 0.9)
    z_c, d_c = tracker_update_cohort(
        zeta0, delta0, _gather(gz[m], idx), ag,
        jnp.asarray(upload[m])[idx], jnp.asarray(idx), has[m], 0.9)
    np.testing.assert_allclose(float(z_ref), float(z_c), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(d_ref), np.asarray(d_c), atol=1e-6)


# ---------------------------------------------------------------------------
# 2-D ("scenario", "clients") mesh parity — subprocess with 4 virtual devices
# ---------------------------------------------------------------------------
SCRIPT_2D = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.fl.runtime import MFLExperiment
from repro.fl.fused_round import draw_round_xs
from repro.launch.mesh import make_population_mesh

exp = MFLExperiment(dataset="iemocap", scheduler="jcsba", K=10, n_samples=150,
                    seed=0, eval_every=10 ** 9, engine="fused")
eng = exp._get_fused_engine()
xs = draw_round_xs(exp, 3)
V = [0.01, 0.3, 2.0]                       # 3 points, scenario axis = 2 -> pad

single = eng.scan_v_grid(V, exp._carry, xs, mesh=None)
mesh = make_population_mesh(n_scenario=2, n_clients=2)
assert mesh is not None and mesh.axis_names == ("scenario", "clients"), mesh
shard = eng.scan_v_grid(V, exp._carry, xs, mesh=mesh)

bit_exact = True
for a, b in zip(jax.tree.leaves(single), jax.tree.leaves(shard)):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, (a.shape, b.shape)
    if not np.array_equal(a, b):
        bit_exact = False
        np.testing.assert_allclose(a, b, rtol=2e-6, atol=1e-7)
ok = np.asarray(shard[1].ok)               # [n_V, R, K]
print(json.dumps({"ok": True, "devices": jax.device_count(),
                  "bit_exact": bit_exact, "n_V": int(ok.shape[0]),
                  "scheduled_any": bool(ok.any())}))
"""


def test_scan_v_grid_2d_mesh_matches_single_device():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT_2D], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 4
    assert out["n_V"] == 3 and out["scheduled_any"]


def test_population_mesh_requires_divisible_K():
    from repro.launch.mesh import make_population_mesh
    # single-device main process: the factory collapses to None like
    # make_sweep_mesh; the divisibility check lives in scan_v_grid and is
    # covered by the subprocess test's 10 % 2 == 0 configuration
    assert make_population_mesh() is None


# ---------------------------------------------------------------------------
# engine= API: spec parsing + deprecation shims
# ---------------------------------------------------------------------------
def _tiny(**kw):
    from repro.fl.runtime import MFLExperiment
    kw.setdefault("eval_every", 10 ** 9)
    return MFLExperiment(dataset="iemocap", scheduler="random",
                         n_samples=120, seed=0, **kw)


def test_engine_spec_parsing_and_defaults():
    assert _tiny().engine == "batched:jax"
    assert _tiny(engine="seq").engine == "seq:jax"
    assert _tiny(engine="fused").engine == "fused:jax"
    with pytest.raises(ValueError):
        _tiny(engine="warp")


def test_legacy_kwargs_removed():
    # the PR-6 ``batched=``/``solver=``/``fused=`` deprecation shims are
    # gone: only the unified engine= spec constructs an experiment
    with pytest.raises(TypeError):
        _tiny(batched=False)
    with pytest.raises(TypeError):
        _tiny(fused=True)
    with pytest.raises(TypeError):
        _tiny(solver="np")
    assert _tiny(engine="seq").engine == "seq:jax"
    assert _tiny(engine="batched:np").engine == "batched:np"


def test_draw_round_xs_eval_every_deprecated():
    from repro.fl.fused_round import draw_round_xs
    exp = _tiny(engine="fused", eval_every=2)
    with pytest.warns(DeprecationWarning):
        xs = draw_round_xs(exp, 4, eval_every=3)
    np.testing.assert_array_equal(np.asarray(xs.eval_flag),
                                  [True, False, False, True])
    # without the deprecated kwarg, the experiment's cadence rules
    xs2 = draw_round_xs(exp, 4)
    np.testing.assert_array_equal(np.asarray(xs2.eval_flag),
                                  [True, False, True, False])


def test_legacy_warm_a_checkpoint_restores_with_warning(tmp_path):
    from repro.checkpoint import save_checkpoint
    from repro.fl.runtime import MFLExperiment
    cfg = dict(dataset="iemocap", scheduler="jcsba", n_samples=150, seed=4,
               eval_every=10 ** 9)
    exp = MFLExperiment(**cfg)
    exp.run(2)
    pol = exp.scheduler.state()
    assert "warm_a" in pol
    # forge a pre-policy checkpoint: warm start as a top-level blob
    state = {"global_params": exp.global_params, "queues_Q": exp.queues.Q,
             "queues_spent": exp.queues.spent,
             "delta": {m: exp.bound.delta[m] for m in exp.all_mods},
             "model_dist": exp.model_dist, "warm_a": pol["warm_a"]}
    meta = {"round": exp._round, "queues_t": exp.queues.t,
            "zeta": {m: float(exp.bound.zeta[m]) for m in exp.all_mods}}
    save_checkpoint(str(tmp_path), state, step=exp._round, metadata=meta)

    twin = MFLExperiment(**cfg)
    with pytest.warns(DeprecationWarning, match="warm_a"):
        assert twin.restore(str(tmp_path)) == 2
    np.testing.assert_array_equal(twin.scheduler.state()["warm_a"],
                                  pol["warm_a"])
    # a fresh save writes the policy/ format only — restoring it is silent
    twin.save(str(tmp_path / "new"))
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error", DeprecationWarning)
        MFLExperiment(**cfg).restore(str(tmp_path / "new"))
