"""Optimizers / checkpointing / data pipeline / paper models."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import optim
from repro.checkpoint import save_checkpoint, load_checkpoint
from repro.data import synthetic
from repro.data.partition import partition, train_test_split
from repro.models import paper_models as pm


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = optim.OPTIMIZERS[name](0.1)
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = optim.apply_updates(params, upd)
    assert float(loss(params)) < 0.1 * l0


def test_adafactor_state_is_factored():
    opt = optim.adafactor(0.01)
    params = {"w": jnp.zeros((64, 32)), "v": jnp.zeros((16,))}
    st_ = opt.init(params)
    assert st_["f"]["w"]["r"].shape == (64,)
    assert st_["f"]["w"]["c"].shape == (32,)
    assert st_["f"]["v"]["v"].shape == (16,)


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(optim.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_schedule():
    lr = optim.warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(99)) < 0.2


# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "c": np.asarray(7, np.int32)}
    save_checkpoint(str(tmp_path), tree, step=3, metadata={"note": "x"})
    loaded, manifest = load_checkpoint(str(tmp_path))
    assert manifest["step"] == 3
    np.testing.assert_array_equal(loaded["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(loaded["c"], tree["c"])


# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(st.integers(4, 12), st.sampled_from([0.2, 0.3, 0.4]),
       st.integers(0, 2 ** 31 - 1))
def test_property_partition_modality_heterogeneity(K, omega, seed):
    ds = synthetic.crema_like(seed=seed % 1000, n=120)
    clients = partition(ds, K, omega, seed=seed % 1000)
    assert len(clients) == K
    total = sum(c.size for c in clients)
    assert total == len(ds)
    n_missing_audio = sum("audio" not in c.modalities for c in clients)
    n_missing_image = sum("image" not in c.modalities for c in clients)
    assert n_missing_audio == int(np.floor(omega * K))
    assert n_missing_image == int(np.floor(omega * K))
    for c in clients:
        assert len(c.modalities) >= 1              # nobody loses everything
        for m in c.modalities:
            assert len(c.dataset.features[m]) == c.size


def test_train_test_split_disjoint():
    ds = synthetic.iemocap_like(seed=0, n=100)
    tr, te = train_test_split(ds, 0.2, seed=0)
    assert len(tr) == 80 and len(te) == 20


# ---------------------------------------------------------------------------
def test_paper_models_shapes():
    k = jax.random.key(0)
    crema = pm.init_crema_model(k)
    audio = jnp.zeros((4, 32, 11))
    image = jnp.zeros((4, 48, 48, 3))
    out = pm.modal_logits(crema, {"audio": audio, "image": image})
    assert out["audio"].shape == (4, 6)
    assert out["image"].shape == (4, 6)
    iemo = pm.init_iemocap_model(k)
    text = jnp.zeros((4, 24, 100))
    out = pm.modal_logits(iemo, {"audio": audio, "text": text})
    assert out["text"].shape == (4, 10)


def test_paper_model_learns_synthetic_audio():
    """The audio LSTM must fit the synthetic CREMA-like audio quickly —
    this is the fast-converging modality of §VI-B."""
    ds = synthetic.crema_like(seed=0, n=200)
    k = jax.random.key(0)
    params = pm.init_lstm_model(k, 11, 50, 6)
    x = jnp.asarray(ds.features["audio"])
    y = jnp.asarray(ds.labels)

    @jax.jit
    def step(p):
        def loss(p):
            lg = pm.lstm_apply(p, x)
            lse = jax.nn.logsumexp(lg, -1)
            gold = jnp.take_along_axis(lg, y[:, None], -1)[:, 0]
            return (lse - gold).mean()
        l, g = jax.value_and_grad(loss)(p)
        return jax.tree.map(lambda a, b: a - 0.3 * b, p, g), l

    for i in range(40):
        params, l = step(params)
    acc = float((jnp.argmax(pm.lstm_apply(params, x), -1) == y).mean())
    assert acc > 0.5, f"audio LSTM failed to learn ({acc})"


def test_param_bits_matches_table2_order():
    """Our LSTM/CNN sizes should be the same order as the paper's l_m
    (562400 / 557056 bits at fp32)."""
    k = jax.random.key(0)
    crema = pm.init_crema_model(k)
    audio_bits = pm.param_bits(crema["audio"])
    image_bits = pm.param_bits(crema["image"])
    assert 1e5 < audio_bits < 5e6
    assert 1e5 < image_bits < 5e6


# ---------------------------------------------------------------------------
def test_stale_bytecode_purge_removes_orphans_only(tmp_path):
    """conftest's session-start guard: a .pyc whose source module was deleted
    must be purged (it would silently shadow the refactor on import); a .pyc
    with a live source must survive."""
    from conftest import _purge_stale_bytecode

    pkg = tmp_path / "src" / "pkg"
    cache = pkg / "__pycache__"
    cache.mkdir(parents=True)
    (pkg / "alive.py").write_text("x = 1\n")
    (cache / "alive.cpython-310.pyc").write_bytes(b"live")
    (cache / "deleted.cpython-310.pyc").write_bytes(b"stale")

    removed = _purge_stale_bytecode(str(tmp_path))
    assert [os.path.basename(p) for p in removed] == \
        ["deleted.cpython-310.pyc"]
    assert (cache / "alive.cpython-310.pyc").exists()
    assert not (cache / "deleted.cpython-310.pyc").exists()
    assert _purge_stale_bytecode(str(tmp_path)) == []   # idempotent
