"""Mini dry-run in a subprocess: the lower/compile path on a small fake-device
mesh (8 devices, 4x2), reduced arch.  Proves the dry-run machinery end-to-end
without the 512-device cost; the full 16x16 / 2x16x16 sweep is
``python -m repro.launch.dryrun --all [--multi-pod]`` (results committed under
benchmarks/results/dryrun/)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shd, steps
from repro.launch.specs import batch_specs, batch_pspecs, InputShape

cfg = get_config("qwen3-0.6b").reduced()
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = InputShape("mini", 128, 8, "train")

pshape = steps.params_shape(cfg)
pspecs = shd.tree_pspecs(pshape, ("data",), mesh=mesh)
opt, _ = steps.make_optimizer(cfg)
oshape = jax.eval_shape(opt.init, pshape)
ospecs = shd.sanitize_tree(shd.opt_state_pspecs(oshape, pshape, ("data",)),
                           oshape, mesh)
bshape = batch_specs(cfg, shape)
bspecs = batch_pspecs(cfg, shape, mesh)
ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
with mesh:
    fn = steps.make_train_step(cfg, opt, n_groups=4, attn_chunk=64)
    lowered = jax.jit(fn, in_shardings=(ns(pspecs), ns(ospecs), ns(bspecs))
                      ).lower(pshape, oshape, bshape)
    compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    print(json.dumps({"ok": True, "flops": float(ca.get("flops", -1)),
                      "devices": jax.device_count()}))
"""


def test_mini_dryrun_subprocess():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 8
    assert out["flops"] > 0


def test_dryrun_results_exist_and_lower():
    """The committed sweep results must show every non-skipped combo ok."""
    d = os.path.join(os.path.dirname(__file__), "..", "benchmarks", "results",
                     "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("dry-run sweep has not been run yet")
    bad = []
    for f in os.listdir(d):
        if not f.endswith(".json"):
            continue
        with open(os.path.join(d, f)) as fh:
            rec = json.load(fh)
        if rec.get("status") not in ("ok", "skipped"):
            bad.append((f, rec.get("error", "?")[:120]))
    assert not bad, f"failed dry-runs: {bad}"
