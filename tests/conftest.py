import os

# Tests must see exactly ONE CPU device (the 512-device flag is dry-run-only;
# the mini dry-run test spawns a subprocess with its own XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
