import importlib.util
import os

# Tests must see exactly ONE CPU device (the 512-device flag is dry-run-only;
# the mini dry-run test spawns a subprocess with its own XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# If the real `hypothesis` is not installed, register the deterministic shim
# BEFORE any test module is imported (property tests then replay a fixed
# example set instead of failing at collection).
_spec = importlib.util.spec_from_file_location(
    "_hypothesis_fallback",
    os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_mod)
_mod.install()

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
