import importlib.util
import os

# Tests must see exactly ONE CPU device (the 512-device flag is dry-run-only;
# the mini dry-run test spawns a subprocess with its own XLA_FLAGS).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _purge_stale_bytecode(repo: str = None) -> list:
    """Delete orphaned ``__pycache__`` bytecode before anything imports.

    A ``.pyc`` whose source module was deleted or renamed silently shadows
    the refactor: ``import foo`` keeps succeeding from the stale cache and
    the suite tests code that no longer exists.  The CI no-bytecode guard
    only protects the *tracked* tree, so local checkouts purge here (the
    matching ``.gitignore`` patterns keep the dirs out of git).  Returns the
    removed paths (exposed for the guard's own sanity check below)."""
    repo = _REPO if repo is None else repo
    removed = []
    for top in ("src", "benchmarks", "tests", "examples"):
        for dirpath, _dirnames, filenames in os.walk(os.path.join(repo, top)):
            if os.path.basename(dirpath) != "__pycache__":
                continue
            srcdir = os.path.dirname(dirpath)
            for fn in filenames:
                if not fn.endswith((".pyc", ".pyo")):
                    continue
                mod = fn.split(".", 1)[0]
                if not os.path.exists(os.path.join(srcdir, mod + ".py")):
                    path = os.path.join(dirpath, fn)
                    os.unlink(path)
                    removed.append(os.path.relpath(path, repo))
    return removed


_purge_stale_bytecode()

# If the real `hypothesis` is not installed, register the deterministic shim
# BEFORE any test module is imported (property tests then replay a fixed
# example set instead of failing at collection).
_spec = importlib.util.spec_from_file_location(
    "_hypothesis_fallback",
    os.path.join(os.path.dirname(__file__), "_hypothesis_fallback.py"))
_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_mod)
_mod.install()

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
