"""Sharding rules + input specs + HLO collective parser unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS
from repro.launch import hlo_analysis, specs
from repro.launch.sharding import param_pspec, tree_pspecs, sanitize_pspec


class _FakeMesh:
    shape = {"data": 16, "model": 16}


def test_param_rules():
    f = ("data",)
    assert param_pspec("blocks/l0/mixer/wq/w", 3, f) == P(None, "data", "model")
    assert param_pspec("blocks/l0/mixer/wo/w", 3, f) == P(None, "model", "data")
    assert param_pspec("blocks/l0/ffn/wg", 4, f) == P(None, "model", "data", None)
    assert param_pspec("blocks/l0/ffn/wg/w", 3, f) == P(None, "data", "model")
    assert param_pspec("embed", 2, f) == P("model", "data")
    assert param_pspec("lm_head", 2, f) == P("data", "model")
    assert param_pspec("blocks/l0/norm1", 2, f) == P(None, None)
    assert param_pspec("blocks/l3/mixer/wx", 3, f) == P(None, "data", "model")


def test_sanitize_drops_nondivisible():
    m = _FakeMesh()
    assert sanitize_pspec(P("model", "data"), (50280, 1024), m) == \
        P(None, "data")
    assert sanitize_pspec(P(None, "model"), (512, 51865), m) == P(None, None)
    assert sanitize_pspec(P("model", None), (256, 7), m) == P("model", None)


def test_input_shape_table():
    s = specs.INPUT_SHAPES
    assert s["train_4k"].seq_len == 4096 and s["train_4k"].global_batch == 256
    assert s["prefill_32k"].global_batch == 32
    assert s["decode_32k"].global_batch == 128
    assert s["long_500k"].seq_len == 524288 and s["long_500k"].global_batch == 1


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_batch_specs_complete(name):
    cfg = ARCHS[name]
    for sh in specs.INPUT_SHAPES.values():
        ok, _ = specs.supports(cfg, sh)
        if not ok:
            continue
        b = specs.batch_specs(cfg, sh)
        if sh.kind == "decode":
            assert set(b) == {"token", "index"}
            assert b["token"].shape == (sh.global_batch, 1)
        else:
            assert b["tokens"].shape == (sh.global_batch, sh.seq_len)
            if cfg.arch_type == "vlm":
                assert "patches" in b
            if cfg.arch_type == "audio":
                assert "src_embeds" in b


def test_long_500k_skip_list():
    skipped = [n for n, c in ARCHS.items()
               if not specs.supports(c, specs.INPUT_SHAPES["long_500k"])[0]]
    assert set(skipped) == {"kimi-k2-1t-a32b", "llama4-scout-17b-a16e",
                            "llava-next-34b", "qwen2-72b", "qwen3-0.6b",
                            "qwen3-4b", "whisper-base"}


# ---------------------------------------------------------------------------
HLO_SAMPLE = """
ENTRY %main (p0: bf16[16,128]) -> bf16[16,128] {
  %ar = bf16[16,128]{1,0} all-reduce(bf16[16,128]{1,0} %p0), replica_groups={{0,1,2,3}}
  ROOT %t = bf16[16,128]{1,0} copy(%ar)
}
%while_body_1 (p: s32[]) -> s32[] {
  %ag = f32[64,256]{1,0} all-gather(f32[4,256]{1,0} %x), replica_groups=[16,16]<=[256], dimensions={0}
  %a2a = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %y), replica_groups={{0,1},{2,3}}
}
"""


def test_hlo_collective_parse():
    ops = hlo_analysis.parse_collectives(HLO_SAMPLE, loop_multiplier=12)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all"]
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.operand_bytes == 16 * 128 * 2
    assert ar.multiplier == 1
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.group_size == 16
    assert ag.operand_bytes == 64 * 256 * 4 // 16
    assert ag.multiplier == 12                     # inside while body
    summ = hlo_analysis.summarize(ops)
    assert summ["total_operand_bytes"] > 0
    assert summ["op_counts"]["all-gather"] == 12
