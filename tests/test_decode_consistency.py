"""Decode-vs-forward consistency: the cached serve path must reproduce the
training forward logits token-by-token (validates RoPE positions, causal
masks, ring-buffer sliding-window caches, and the Mamba2 chunked-vs-recurrent
duality)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T, encdec
from repro.launch import steps


def _teacher_force(cfg, params, tokens):
    B, S = tokens.shape
    cache = T.init_cache(cfg, B, S, jnp.float32)
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    outs = []
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("name", ["qwen3-0.6b", "gemma3-12b", "mamba2-370m",
                                  "jamba-v0.1-52b", "kimi-k2-1t-a32b"])
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced()
    if cfg.ssm_state:
        cfg = dataclasses.replace(cfg, ssm_chunk=8)
    if cfg.n_experts:
        # capacity dropping is train-path-only behaviour; give the router
        # enough capacity that no token is dropped, so the two paths must
        # agree exactly (drop behaviour itself is tested in test_moe.py)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(0)
    B, S = 2, 32
    params = steps.init_fn(cfg)(jax.random.key(1))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = T.forward(params, tokens, cfg, n_groups=1, attn_chunk=8)
    dec_logits = _teacher_force(cfg, params, tokens)
    err = float(jnp.abs(full_logits - dec_logits).max())
    scale = float(jnp.abs(full_logits).max())
    assert err < 2e-3 * max(scale, 1.0), f"{name}: decode diverges ({err})"


def test_sliding_window_ring_buffer():
    """Windowed decode cache smaller than the sequence still matches the
    windowed training forward (ring-buffer correctness)."""
    cfg = ARCHS["gemma3-12b"].reduced()
    # all-local tiny config: window 8, 12 layers -> ring buffer wraps at S=32
    cfg = dataclasses.replace(cfg, sliding_window=8)
    rng = np.random.default_rng(0)
    B, S = 1, 32
    params = steps.init_fn(cfg)(jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = T.forward(params, tokens, cfg, n_groups=1, attn_chunk=8)
    dec_logits = _teacher_force(cfg, params, tokens)
    err = float(jnp.abs(full_logits - dec_logits).max())
    scale = float(jnp.abs(full_logits).max())
    assert err < 2e-3 * max(scale, 1.0), f"ring buffer diverges ({err})"


def test_whisper_decode_matches_forward():
    cfg = ARCHS["whisper-base"].reduced()
    rng = np.random.default_rng(0)
    B, S, SRC = 2, 16, 24
    params = steps.init_fn(cfg)(jax.random.key(0))
    src = jnp.asarray(rng.normal(size=(B, SRC, cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    enc = encdec.encode(params, src, cfg, attn_chunk=8)
    full = encdec.decode_fwd(params, tokens, enc, cfg, attn_chunk=8)

    from repro.models import layers as L
    cache = encdec.init_dec_cache(cfg, B, S, SRC, jnp.float32)
    ck, cv = [], []
    for i in range(cfg.n_layers):
        bp = jax.tree.map(lambda x: x[i], params["dec_blocks"])
        ck.append(L.dense(bp["cross_attn"]["wk"], enc).reshape(
            B, SRC, cfg.n_kv_heads, cfg.hd))
        cv.append(L.dense(bp["cross_attn"]["wv"], enc).reshape(
            B, SRC, cfg.n_kv_heads, cfg.hd))
    cache["cross_k"] = jnp.stack(ck)
    cache["cross_v"] = jnp.stack(cv)

    step = jax.jit(lambda p, c, t, i: encdec.decode_step(p, c, t, i, cfg))
    outs = []
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(full - dec).max())
    scale = float(jnp.abs(full).max())
    assert err < 2e-3 * max(scale, 1.0), f"whisper decode diverges ({err})"
