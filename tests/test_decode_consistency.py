"""Decode-vs-forward consistency: the cached serve path must reproduce the
training forward logits token-by-token (validates RoPE positions, causal
masks, ring-buffer sliding-window caches, and the Mamba2 chunked-vs-recurrent
duality)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import transformer as T, encdec
from repro.launch import steps


def _teacher_force(cfg, params, tokens):
    B, S = tokens.shape
    cache = T.init_cache(cfg, B, S, jnp.float32)
    step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    outs = []
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    return jnp.stack(outs, axis=1)


@pytest.mark.parametrize("name", ["qwen3-0.6b", "gemma3-12b", "mamba2-370m",
                                  "jamba-v0.1-52b", "kimi-k2-1t-a32b"])
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced()
    if cfg.ssm_state:
        cfg = dataclasses.replace(cfg, ssm_chunk=8)
    if cfg.n_experts:
        # capacity dropping is train-path-only behaviour; give the router
        # enough capacity that no token is dropped, so the two paths must
        # agree exactly (drop behaviour itself is tested in test_moe.py)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    rng = np.random.default_rng(0)
    B, S = 2, 32
    params = steps.init_fn(cfg)(jax.random.key(1))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = T.forward(params, tokens, cfg, n_groups=1, attn_chunk=8)
    dec_logits = _teacher_force(cfg, params, tokens)
    err = float(jnp.abs(full_logits - dec_logits).max())
    scale = float(jnp.abs(full_logits).max())
    assert err < 2e-3 * max(scale, 1.0), f"{name}: decode diverges ({err})"


def test_sliding_window_ring_buffer():
    """Windowed decode cache smaller than the sequence still matches the
    windowed training forward (ring-buffer correctness)."""
    cfg = ARCHS["gemma3-12b"].reduced()
    # all-local tiny config: window 8, 12 layers -> ring buffer wraps at S=32
    cfg = dataclasses.replace(cfg, sliding_window=8)
    rng = np.random.default_rng(0)
    B, S = 1, 32
    params = steps.init_fn(cfg)(jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    full_logits, _ = T.forward(params, tokens, cfg, n_groups=1, attn_chunk=8)
    dec_logits = _teacher_force(cfg, params, tokens)
    err = float(jnp.abs(full_logits - dec_logits).max())
    scale = float(jnp.abs(full_logits).max())
    assert err < 2e-3 * max(scale, 1.0), f"ring buffer diverges ({err})"


def test_whisper_decode_matches_forward():
    cfg = ARCHS["whisper-base"].reduced()
    rng = np.random.default_rng(0)
    B, S, SRC = 2, 16, 24
    params = steps.init_fn(cfg)(jax.random.key(0))
    src = jnp.asarray(rng.normal(size=(B, SRC, cfg.d_model)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    enc = encdec.encode(params, src, cfg, attn_chunk=8)
    full = encdec.decode_fwd(params, tokens, enc, cfg, attn_chunk=8)

    from repro.models import layers as L
    cache = encdec.init_dec_cache(cfg, B, S, SRC, jnp.float32)
    ck, cv = [], []
    for i in range(cfg.n_layers):
        bp = jax.tree.map(lambda x: x[i], params["dec_blocks"])
        ck.append(L.dense(bp["cross_attn"]["wk"], enc).reshape(
            B, SRC, cfg.n_kv_heads, cfg.hd))
        cv.append(L.dense(bp["cross_attn"]["wv"], enc).reshape(
            B, SRC, cfg.n_kv_heads, cfg.hd))
    cache["cross_k"] = jnp.stack(ck)
    cache["cross_v"] = jnp.stack(cv)

    step = jax.jit(lambda p, c, t, i: encdec.decode_step(p, c, t, i, cfg))
    outs = []
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.abs(full - dec).max())
    scale = float(jnp.abs(full).max())
    assert err < 2e-3 * max(scale, 1.0), f"whisper decode diverges ({err})"


# ---------------------------------------------------------------------------
# bulk prefill (launch serving hot path)
# ---------------------------------------------------------------------------
def _tweaked(name):
    cfg = ARCHS[name].reduced()
    if cfg.ssm_state:
        cfg = dataclasses.replace(cfg, ssm_chunk=8)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


@pytest.mark.parametrize("name", ["qwen3-0.6b", "gemma3-12b", "mamba2-370m",
                                  "jamba-v0.1-52b", "kimi-k2-1t-a32b"])
def test_bulk_prefill_matches_teacher_forced(name):
    """One chunked prefill pass must leave the cache exactly where S
    teacher-forced decode steps leave it — subsequent decode continues
    identically from either."""
    cfg = _tweaked(name)
    rng = np.random.default_rng(2)
    B, S, EXTRA = 2, 24, 4
    params = steps.init_fn(cfg)(jax.random.key(1))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    serve_step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))

    cache_tf = T.init_cache(cfg, B, S + EXTRA, jnp.float32)
    for i in range(S):
        logits_tf, cache_tf = serve_step(params, cache_tf,
                                         prompts[:, i:i + 1], jnp.int32(i))

    bulk = jax.jit(steps.make_bulk_prefill(cfg, attn_chunk=8))
    nxt, cache_bulk = bulk(params, prompts,
                           T.init_cache(cfg, B, S + EXTRA, jnp.float32))

    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(logits_tf[:, 0].argmax(-1)))
    for pa, pb in zip(jax.tree.leaves(cache_tf), jax.tree.leaves(cache_bulk)):
        c_err = float(jnp.abs(pa.astype(jnp.float32)
                              - pb.astype(jnp.float32)).max())
        assert c_err < 2e-3 * max(float(jnp.abs(pa).max()), 1.0), (name, c_err)
    # continued decode from each cache stays token-identical
    ta, tb = nxt, nxt
    ca, cb = cache_tf, cache_bulk
    for i in range(EXTRA):
        la, ca = serve_step(params, ca, ta, jnp.int32(S + i))
        lb, cb = serve_step(params, cb, tb, jnp.int32(S + i))
        l_err = float(jnp.abs(la - lb).max())
        assert l_err < 2e-3 * max(float(jnp.abs(la).max()), 1.0), (name, l_err)
        ta = la.argmax(-1).astype(jnp.int32)
        tb = lb.argmax(-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


def test_bulk_prefill_sliding_window_ring_buffer():
    """Prompt longer than the window: the bulk fill must land the live
    window into the ring-buffer slots exactly as per-token decode does."""
    cfg = dataclasses.replace(ARCHS["gemma3-12b"].reduced(), sliding_window=8)
    rng = np.random.default_rng(0)
    B, S = 1, 24                       # cache size = S, window 8 wraps
    params = steps.init_fn(cfg)(jax.random.key(0))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    serve_step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    cache_tf = T.init_cache(cfg, B, S, jnp.float32)
    for i in range(S):
        logits_tf, cache_tf = serve_step(params, cache_tf,
                                         prompts[:, i:i + 1], jnp.int32(i))
    bulk = jax.jit(steps.make_bulk_prefill(cfg, attn_chunk=8))
    nxt, cache_bulk = bulk(params, prompts, T.init_cache(cfg, B, S,
                                                         jnp.float32))
    for pa, pb in zip(jax.tree.leaves(cache_tf), jax.tree.leaves(cache_bulk)):
        err = float(jnp.abs(pa.astype(jnp.float32)
                            - pb.astype(jnp.float32)).max())
        assert err < 2e-3 * max(float(jnp.abs(pa).max()), 1.0), err
    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(logits_tf[:, 0].argmax(-1)))


def test_whisper_cross_kv_matches_loop_and_bulk_prefill():
    """The stacked-einsum cross-K/V equals the per-layer loop, and the bulk
    decoder prefill continues decode identically to teacher forcing."""
    from repro.models import layers as L
    cfg = ARCHS["whisper-base"].reduced()
    rng = np.random.default_rng(1)
    B, S, SRC, EXTRA = 2, 12, 16, 4
    params = steps.init_fn(cfg)(jax.random.key(2))
    src = jnp.asarray(rng.normal(size=(B, SRC, cfg.d_model)), jnp.float32)
    enc = encdec.encode(params, src, cfg, attn_chunk=8)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    # (a) stacked einsum vs per-layer loop
    ck, cv = encdec.cross_kv(params, enc, cfg)
    for i in range(cfg.n_layers):
        bp = jax.tree.map(lambda x: x[i], params["dec_blocks"])
        k_ref = L.dense(bp["cross_attn"]["wk"], enc).reshape(
            B, SRC, cfg.n_kv_heads, cfg.hd)
        v_ref = L.dense(bp["cross_attn"]["wv"], enc).reshape(
            B, SRC, cfg.n_kv_heads, cfg.hd)
        np.testing.assert_allclose(np.asarray(ck[i]), np.asarray(k_ref),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(cv[i]), np.asarray(v_ref),
                                   rtol=1e-5, atol=1e-5)

    # (b) bulk prefill vs teacher forcing, continued decode token parity
    def fresh_cache():
        c = encdec.init_dec_cache(cfg, B, S + EXTRA, SRC, jnp.float32)
        c["cross_k"], c["cross_v"] = ck, cv
        return c

    step = jax.jit(lambda p, c, t, i: encdec.decode_step(p, c, t, i, cfg))
    cache_tf = fresh_cache()
    for i in range(S):
        logits_tf, cache_tf = step(params, cache_tf, tokens[:, i:i + 1],
                                   jnp.int32(i))
    bulk = jax.jit(steps.make_bulk_prefill(cfg, attn_chunk=8))
    nxt, cache_bulk = bulk(params, tokens, enc, fresh_cache())
    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(logits_tf[:, 0].argmax(-1)))
    ta = tb = nxt
    ca, cb = cache_tf, cache_bulk
    for i in range(EXTRA):
        la, ca = step(params, ca, ta, jnp.int32(S + i))
        lb, cb = step(params, cb, tb, jnp.int32(S + i))
        err = float(jnp.abs(la - lb).max())
        assert err < 2e-3 * max(float(jnp.abs(la).max()), 1.0), err
        ta = la.argmax(-1).astype(jnp.int32)
        tb = lb.argmax(-1).astype(jnp.int32)
        np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


# ---------------------------------------------------------------------------
# hot-swap decode consistency (launch/continuous)
# ---------------------------------------------------------------------------
def test_hot_swap_decode_is_bit_identical_to_fresh_engine():
    """Swapping params mid-stream must produce, from the swap step onward,
    exactly the tokens a FRESH engine with the new params and the same cache
    state would produce."""
    from repro.launch.continuous import ContinuousServer
    cfg = ARCHS["qwen3-0.6b"].reduced()
    rng = np.random.default_rng(0)
    B, S, PRE, POST = 2, 12, 5, 8
    feats = {"audio": jnp.asarray(rng.normal(size=(B, 20, 11)), jnp.float32),
             "text": jnp.asarray(rng.normal(size=(B, 30, 100)), jnp.float32)}
    from repro.models import paper_models
    fusion_a = paper_models.init_iemocap_model(jax.random.key(10))
    fusion_b = paper_models.init_iemocap_model(jax.random.key(11))
    lm = steps.init_fn(cfg)(jax.random.key(1))
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    srv = ContinuousServer(cfg, lm, fusion_a, feats, max_len=S + PRE + POST)
    srv.start(prompts)
    for _ in range(PRE):
        srv.decode_step()
    st = srv.state()

    # stream A: hot-swap to fusion_b, continue decoding
    srv.swap(fusion_b)
    toks_swapped = []
    for _ in range(POST):
        srv.decode_step()
        toks_swapped.append(np.asarray(srv.token))

    # stream B: FRESH engine built with fusion_b, same cache state restored
    srv2 = ContinuousServer(cfg, lm, fusion_b, feats,
                            max_len=S + PRE + POST)
    srv2.load_state(st)
    toks_fresh = []
    for _ in range(POST):
        srv2.decode_step()
        toks_fresh.append(np.asarray(srv2.token))

    np.testing.assert_array_equal(np.stack(toks_swapped),
                                  np.stack(toks_fresh))
