"""Property suite for the corrected missing-modality partitioner.

Locks the PR-8 substrate contract: for any feasible per-modality ω_m the
missing sets keep every client ≥1 modality and every modality ≥1 owner;
realized sizes equal ⌊ω_m·K⌋ whenever the keep-≥1 capacity allows and the
documented water-fill shave otherwise; genuinely infeasible specs raise
``ValueError`` instead of silently wrapping (the old cursor wrap made
per-modality missing sets overlap for ω > 1/M — ``partition`` crashed on
ω=0.6, M=2 and ``synthetic_population`` emitted zero-modality clients).
"""
import numpy as np
import pytest

from repro.data.partition import (missing_counts, missing_masks,
                                  normalize_omegas, partition,
                                  stack_clients, synthetic_population)
from repro.data.synthetic import DATASETS


def _mask_stack(store):
    return np.stack([np.asarray(store.has_modality[m])
                     for m in store.modalities])


# ---------------------------------------------------------------------------
# the exact pre-fix failure
# ---------------------------------------------------------------------------
def test_regression_omega_06_two_modalities():
    """ω=0.6, M=2: the old wrap-around overlap tripped partition's
    "client lost every modality" assert and left synthetic_population with
    dead clients.  Both must now run clean."""
    ds = DATASETS["iemocap"](seed=0, n=60)
    clients = partition(ds, 10, 0.6, seed=0)
    assert all(len(c.modalities) >= 1 for c in clients)
    store = synthetic_population(10, 4, {"audio": (4,), "text": (3,)}, 4,
                                 0.6, seed=0)
    has = _mask_stack(store)
    assert has.any(axis=0).all(), "client with zero modalities"
    assert has.any(axis=1).all(), "modality with zero owners"


# ---------------------------------------------------------------------------
# realized counts
# ---------------------------------------------------------------------------
def test_missing_counts_exact_in_feasible_regime():
    for K in (7, 10, 24):
        for om in ([0.0, 0.0], [0.3, 0.3], [0.1, 0.4], [0.2, 0.2, 0.2]):
            counts = missing_counts(K, om)
            assert counts.tolist() == [int(np.floor(w * K)) for w in om]


def test_missing_counts_water_fill_shave():
    # capacity K(M-1): oversubscribed targets shave largest-first
    assert missing_counts(10, [0.6, 0.6]).tolist() == [5, 5]
    assert missing_counts(10, [0.9, 0.9, 0.9]).tolist() == [7, 7, 6]
    # asymmetric: the small target is preserved, the big one pays
    assert missing_counts(10, [0.9, 0.3]).tolist() == [7, 3]
    # total never exceeds capacity, per-modality never exceeds its target
    for om in ([0.8, 0.8], [0.9, 0.5, 0.7]):
        c = missing_counts(10, om)
        assert c.sum() <= 10 * (len(om) - 1)
        assert (c <= np.floor(np.asarray(om) * 10)).all()


def test_missing_counts_infeasible_raises():
    with pytest.raises(ValueError):
        missing_counts(10, [1.0, 0.2])          # ω_m = 1: modality unowned
    with pytest.raises(ValueError):
        missing_counts(10, [-0.1, 0.2])
    with pytest.raises(ValueError):
        missing_counts(10, [0.5])               # M = 1: only modality


def test_normalize_omegas_broadcasts():
    mods = ("audio", "text")
    assert normalize_omegas(0.3, mods) == (0.3, 0.3)
    assert normalize_omegas([0.1, 0.2], mods) == (0.1, 0.2)
    assert normalize_omegas({"text": 0.4}, mods) == (0.0, 0.4)
    with pytest.raises(ValueError):
        normalize_omegas([0.1], mods)           # wrong length
    with pytest.raises(ValueError):
        normalize_omegas({"video": 0.1}, mods)  # unknown modality


# ---------------------------------------------------------------------------
# property sweep: ω ∈ [0, 0.9] × M ∈ {2, 3}
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M", [2, 3])
def test_property_masks_across_omega_sweep(M):
    K = 12
    rng_seeds = [0, 1, 2]
    for omega in np.linspace(0.0, 0.9, 10):
        counts = missing_counts(K, [omega] * M)
        feasible = M * int(np.floor(omega * K)) <= K * (M - 1)
        if feasible:
            assert (counts == int(np.floor(omega * K))).all()
        for seed in rng_seeds:
            miss = missing_masks(K, [omega] * M,
                                 np.random.default_rng(seed))
            assert miss.shape == (M, K)
            # realized per-modality sizes match the exposed counts
            assert (miss.sum(axis=1) == counts).all()
            # every client keeps >= 1 modality, every modality >= 1 owner
            assert not miss.all(axis=0).any()
            assert not miss.all(axis=1).any()


@pytest.mark.parametrize("M", [2, 3])
def test_property_synthetic_population_sweep(M):
    shapes = {f"m{i}": (3,) for i in range(M)}
    for omega in np.linspace(0.0, 0.9, 10):
        store = synthetic_population(12, 4, shapes, 5, float(omega), seed=3)
        has = _mask_stack(store)
        assert has.any(axis=0).all()
        assert has.any(axis=1).all()
        # non-owners carry exact-zero feature blocks
        for i, m in enumerate(store.modalities):
            gone = ~has[i]
            if gone.any():
                assert not np.asarray(store.features[m])[gone].any()


def test_synthetic_population_matches_partition_mask_statistics():
    """The two builders share the missing_counts/missing_masks construction:
    at matched (K, ω, seed) the per-modality missing-set sizes agree
    exactly (membership may differ — partition's rng consumes shard draws
    first)."""
    K = 10
    ds = DATASETS["iemocap"](seed=5, n=60)
    for omega in (0.0, 0.2, 0.4, 0.6):
        clients = partition(ds, K, omega, seed=5)
        stacked = stack_clients(clients, sorted(ds.features))
        store = synthetic_population(K, 4, {"audio": (4,), "text": (3,)},
                                     4, omega, seed=5)
        for m in ("audio", "text"):
            assert (np.asarray(stacked.has_modality[m]).sum()
                    == np.asarray(store.has_modality[m]).sum()), (m, omega)


def test_per_modality_omega_vectors():
    K = 10
    ds = DATASETS["iemocap"](seed=1, n=60)
    clients = partition(ds, K, {"audio": 0.5, "text": 0.2}, seed=1)
    n_missing = {m: sum(m not in c.modalities for c in clients)
                 for m in ("audio", "text")}
    assert n_missing == {"audio": 5, "text": 2}
    store = synthetic_population(K, 4, {"audio": (4,), "text": (3,)}, 4,
                                 (0.5, 0.2), seed=1)
    assert int((~np.asarray(store.has_modality["audio"])).sum()) == 5
    assert int((~np.asarray(store.has_modality["text"])).sum()) == 2


# ---------------------------------------------------------------------------
# class-conditional population features
# ---------------------------------------------------------------------------
def test_synthetic_population_class_structure():
    """Features must carry class signal (the old builder emitted pure noise,
    so population-scale eval was chance-level by construction)."""
    store = synthetic_population(8, 64, {"a": (6,)}, 3, 0.0, seed=2,
                                 snr=2.0)
    x = np.asarray(store.features["a"]).reshape(-1, 6)
    y = np.asarray(store.labels).reshape(-1)
    mus = np.stack([x[y == c].mean(axis=0) for c in range(3)])
    gaps = [np.linalg.norm(mus[i] - mus[j])
            for i in range(3) for j in range(i + 1, 3)]
    # class means separated well beyond the noise floor of the estimate
    assert min(gaps) > 5 * 6 / np.sqrt(len(y) / 3)


def test_synthetic_population_per_modality_snr():
    kw = dict(K=6, n_per_client=32, feature_shapes={"a": (4,), "b": (4,)},
              n_classes=3, omega=0.0, seed=4)
    store = synthetic_population(snr={"a": 3.0, "b": 0.0}, **kw)
    y = np.asarray(store.labels).reshape(-1)

    def class_spread(m):
        x = np.asarray(store.features[m]).reshape(-1, 4)
        mus = np.stack([x[y == c].mean(axis=0) for c in range(3)])
        return np.linalg.norm(mus - mus.mean(0))
    assert class_spread("a") > 3 * class_spread("b")


# ---------------------------------------------------------------------------
# Dirichlet shard rebalancing (satellite 3)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("alpha", [0.1, 0.5, 1.0])
@pytest.mark.parametrize("K", [10, 50])
def test_property_dirichlet_shards_rebalance(alpha, K):
    """No shard ends empty and donated indices stay unique (a donor is never
    popped to empty), even when K is large relative to the per-class sample
    count."""
    ds = DATASETS["iemocap"](seed=7, n=max(60, K + 10))
    clients = partition(ds, K, 0.0, seed=int(alpha * 10) + K,
                        dirichlet_alpha=alpha)
    assert len(clients) == K
    sizes = [c.size for c in clients]
    assert min(sizes) >= 1
    # every sample assigned exactly once across clients (move, not copy)
    assert sum(sizes) == len(ds)
    all_labels = np.concatenate([c.dataset.labels for c in clients])
    assert sorted(all_labels.tolist()) == sorted(ds.labels.tolist())


def test_dirichlet_shards_too_few_samples_raises():
    ds = DATASETS["iemocap"](seed=7, n=30)
    with pytest.raises(ValueError):
        partition(ds, 50, 0.0, seed=0, dirichlet_alpha=0.1)


def test_dirichlet_alpha_plumbs_through_experiment():
    """runtime.py used to drop dirichlet_alpha on the floor — the label-skew
    path was dead code from the experiment API."""
    from repro.fl.runtime import MFLExperiment
    cfg = dict(dataset="iemocap", scheduler="random", K=6, n_samples=120,
               seed=0, eval_every=10 ** 9)
    iid = MFLExperiment(**cfg)
    skew = MFLExperiment(dirichlet_alpha=0.1, **cfg)
    assert sum(iid.data_sizes) == sum(skew.data_sizes)
    # α=0.1 label skew makes shard sizes ragged; IID shards stay equal-ish
    assert max(iid.data_sizes) - min(iid.data_sizes) <= 1
    assert np.std(skew.data_sizes) > np.std(iid.data_sizes)
