"""Continuous serving under live MFL training (launch/continuous): the
interleaved rounds/decode driver must hot-swap at every round boundary with
ZERO post-warmup recompiles, and the swap must actually change the serving
params (the bias head sees each round's fresh fusion params)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.fl.runtime import MFLExperiment
from repro.launch import steps
from repro.launch.continuous import ContinuousServer, run_continuous


def _setup(rounds=2, steps_per_round=4, B=2, S=12):
    cfg = ARCHS["qwen3-0.6b"].reduced()
    exp = MFLExperiment(dataset="iemocap", scheduler="jcsba", K=6,
                        n_samples=120, seed=0, eval_every=10 ** 9,
                        engine="fused")
    feats = {m: jnp.asarray(x[:B])
             for m, x in sorted(exp.test_ds.features.items())}
    lm = steps.init_fn(cfg)(jax.random.key(0))
    server = ContinuousServer(
        cfg, lm, exp.global_params, feats,
        max_len=S + 8 + rounds * steps_per_round)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S))
    return cfg, exp, server, prompts


def test_continuous_zero_recompiles_and_swaps():
    rounds, spr = 2, 4
    cfg, exp, server, prompts = _setup(rounds, spr)
    rep = run_continuous(exp, server, prompts, rounds=rounds,
                         steps_per_round=spr, warmup_steps=2)
    # the headline contract: nothing retraced after warmup
    assert sum(rep["recompiles"].values()) == 0, rep["recompiles"]
    assert rep["compile_counts"]["decode_traces"] == 1
    assert rep["compile_counts"]["prefill_traces"] == 1
    assert len(rep["swap_walls_s"]) == rounds
    assert len(rep["round_walls_s"]) == rounds
    assert len(rep["post_swap_latencies_s"]) == rounds
    assert len(rep["steady_latencies_s"]) == rounds * (spr - 1)
    assert rep["tokens_decoded"] == server.batch * rounds * spr
    assert rep["tokens_per_s"] > 0


def test_swap_updates_serving_params():
    from repro.launch import parambuf
    cfg, exp, server, prompts = _setup()
    server.start(jnp.asarray(prompts, jnp.int32))
    before = jax.tree.map(
        np.asarray, parambuf.unpack(server.bufs, server.spec)["fusion"])
    bias_before = np.asarray(server.bias)
    exp.run_scanned(1)
    eng = exp._get_fused_engine()
    server.swap(eng.round_params(exp._carry))
    after = parambuf.unpack(server.bufs, server.spec)
    # training moved the fusion params; lm/coupling untouched
    moved = any(float(jnp.abs(jnp.asarray(b) - a).max()) > 0
                for b, a in zip(jax.tree.leaves(before),
                                jax.tree.leaves(after["fusion"])))
    assert moved
    for a, b in zip(jax.tree.leaves(server._lm),
                    jax.tree.leaves(after["lm"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(np.abs(np.asarray(server.bias) - bias_before).max()) > 0


def test_audio_arch_rejected():
    cfg = ARCHS["whisper-base"].reduced()
    with pytest.raises(NotImplementedError):
        ContinuousServer(cfg, {}, {}, {"audio": jnp.zeros((1, 4, 11))},
                         max_len=8)
