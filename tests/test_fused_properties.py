"""Property tests for the fused round engine's state algebra.

Runs under real ``hypothesis`` when installed, else under the deterministic
shim in tests/_hypothesis_fallback.py (registered by conftest).  Properties:

* Lyapunov queues stay non-negative under any recursion of
  ``lyapunov.queue_update`` (numpy and jnp backends agree);
* ``ClientCost.tau_residual`` is monotone in τ_max (the In1 budget can only
  grow with the latency budget);
* the fused carry round-trips through tree flatten/unflatten unchanged — the
  structural invariant ``lax.scan`` relies on;
* drop-bit semantics of the traced dropout baseline [28]: a dropped modality
  never contributes to the Eq. 12 aggregation weights, no client is ever
  dropped to zero modalities, and a client's drop draws depend on exactly
  (round key, client index) — never on the rest of the cohort.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import stacked_weights_traced, upload_masks_traced
from repro.fl.fused_round import FusedCarry, RoundAux, RoundXs
from repro.wireless.cost import ClientCost
from repro.wireless.lyapunov import queue_update
from repro.wireless.params import WirelessParams
from repro.wireless.policies import DropoutPolicy, dropout_draws


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 32), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.1))
def test_queue_update_nonnegative_recursion(K, seed, E_add):
    rng = np.random.default_rng(seed)
    Q = rng.uniform(0, 1.0, K)
    for _ in range(5):
        used = rng.uniform(0, 0.5, K) * rng.integers(0, 2, K)
        Qn = np.asarray(queue_update(Q, used, E_add))
        assert (Qn >= 0).all()
        np.testing.assert_allclose(Qn, np.maximum(Q - (E_add - used), 0))
        # backend-agnostic: jnp recursion matches numpy to f32 tolerance
        Qj = queue_update(jnp.asarray(Q, jnp.float32),
                          jnp.asarray(used, jnp.float32), E_add)
        np.testing.assert_allclose(np.asarray(Qj), Qn, rtol=1e-5, atol=1e-6)
        Q = Qn


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2 ** 31 - 1),
       st.floats(1e-4, 0.05), st.floats(0.0, 0.05))
def test_tau_residual_monotone_in_tau_max(K, seed, tau_lo, tau_gap):
    rng = np.random.default_rng(seed)
    cost = ClientCost(gamma_bits=rng.uniform(1e5, 1e6, K),
                      tau_cmp=rng.uniform(0, 0.02, K),
                      e_cmp=rng.uniform(0, 0.01, K))
    lo = cost.tau_residual(WirelessParams(tau_max=tau_lo))
    hi = cost.tau_residual(WirelessParams(tau_max=tau_lo + tau_gap))
    assert (hi >= lo).all()
    np.testing.assert_allclose(hi - lo, tau_gap, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
def test_fused_carry_tree_roundtrip(K, M, seed):
    rng = np.random.default_rng(seed)
    mods = [f"m{i}" for i in range(M)]
    carry = FusedCarry(
        params={m: {"w": jnp.asarray(rng.normal(size=(4, 2)), jnp.float32),
                    "b": jnp.asarray(rng.normal(size=(2,)), jnp.float32)}
                for m in mods},
        policy={"warm_a": jnp.asarray(rng.integers(0, 2, K), bool)},
        Q=jnp.asarray(rng.uniform(0, 1, K), jnp.float32),
        spent=jnp.asarray(rng.uniform(0, 1, K), jnp.float32),
        zeta=jnp.asarray(rng.uniform(0, 2, M), jnp.float32),
        delta=jnp.asarray(rng.uniform(0, 1, (M, K)), jnp.float32),
        model_dist=jnp.asarray(rng.uniform(0, 1, K), jnp.float32))
    leaves, treedef = jax.tree_util.tree_flatten(carry)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, FusedCarry)
    assert jax.tree_util.tree_structure(rebuilt) == treedef
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(rebuilt)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identity-mapping through jax.tree.map preserves the NamedTuple type
    mapped = jax.tree.map(lambda x: x, carry)
    assert isinstance(mapped, FusedCarry)


def test_round_pytrees_scan_compatible():
    """RoundXs/RoundAux slice along a leading axis like lax.scan needs."""
    K, R = 4, 3
    xs = RoundXs(h=jnp.zeros((R, K)), draw_seed=jnp.zeros(R, jnp.uint32),
                 client_seeds=jnp.zeros((R, K), jnp.uint32),
                 eval_flag=jnp.zeros(R, bool))
    x0 = jax.tree.map(lambda x: x[0], xs)
    assert isinstance(x0, RoundXs) and x0.h.shape == (K,)
    assert x0.eval_flag.shape == ()
    aux = RoundAux(a=jnp.zeros(K, bool), ok=jnp.zeros(K, bool),
                   J=jnp.float32(0), weights={"m": jnp.zeros(K)},
                   energy_total=jnp.float32(0),
                   drop={"m": jnp.zeros(K, bool)},
                   metrics={"multimodal": jnp.float32(jnp.nan)},
                   eval_mask=jnp.zeros((), bool))
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), aux)
    assert isinstance(stacked, RoundAux)
    assert stacked.weights["m"].shape == (2, K)
    assert stacked.drop["m"].shape == (2, K)
    assert stacked.metrics["multimodal"].shape == (2,)


# ---------------------------------------------------------------------------
# drop-bit semantics of the traced dropout baseline [28]
# ---------------------------------------------------------------------------
def _random_cohort(rng, K, M=3):
    """Random modality ownership with ≥1 modality per client."""
    names = [f"m{i}" for i in range(M)]
    mods = []
    for _ in range(K):
        n = int(rng.integers(1, M + 1))
        mods.append(tuple(rng.choice(names, size=n, replace=False)))
    return mods


def _drop_round(K, seed, p_drop, n_sched=None):
    rng = np.random.default_rng(seed)
    mods = _random_cohort(rng, K)
    pol = DropoutPolicy.from_modalities(K, mods, n_sched or max(K // 2, 1),
                                        p_drop)
    _, a, _B, _J, drop, _idx = pol.step_full(
        {}, {"B_max": jnp.float32(10e6)}, jnp.zeros(K, jnp.float32),
        jax.random.PRNGKey(seed))
    return pol, np.asarray(a), np.asarray(drop)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0))
def test_dropped_modality_never_weighted(K, seed, p_drop):
    """A dropped modality is excluded from the Eq. 12 upload masks, so its
    aggregation weight is exactly zero — whatever the participation set."""
    pol, a, drop = _drop_round(K, seed, p_drop)
    has = {m: jnp.asarray(np.asarray(pol.owns)[i], bool)
           for i, m in enumerate(pol.drop_mods)}
    drop_d = {m: jnp.asarray(drop[i], bool)
              for i, m in enumerate(pol.drop_mods)}
    upload = upload_masks_traced(jnp.asarray(a, bool), has, drop_d)
    D = np.random.default_rng(seed).integers(1, 100, K)
    w = stacked_weights_traced(jnp.asarray(D, jnp.float32), upload)
    for i, m in enumerate(pol.drop_mods):
        w_m = np.asarray(w[m])
        assert (w_m[drop[i]] == 0).all()
        assert (w_m[~np.asarray(pol.owns)[i]] == 0).all()
        tot = w_m.sum()
        assert tot == 0 or abs(tot - 1.0) < 1e-5


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2 ** 31 - 1), st.floats(0.0, 1.0))
def test_no_client_dropped_to_zero_modalities(K, seed, p_drop):
    """Unimodal clients never drop; multimodal clients drop at most one
    owned modality — so every scheduled client keeps ≥1 modality."""
    pol, a, drop = _drop_round(K, seed, p_drop)
    owns = np.asarray(pol.owns)
    n_owned = owns.sum(0)
    assert (drop <= owns).all()                     # drops are owned
    assert (drop.sum(0) <= 1).all()                 # at most one per client
    assert (drop.sum(0)[n_owned <= 1] == 0).all()   # unimodal never drops
    assert ((n_owned - drop.sum(0)) >= 1).all()     # never to zero
    assert (drop.sum(0) <= a).all()                 # only scheduled clients


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_drop_draws_depend_only_on_key_and_client_index(K, extra, seed):
    """Growing the cohort must not perturb the surviving clients' drop
    draws: ``dropout_draws`` is a per-client ``fold_in`` of the round key."""
    key = jax.random.PRNGKey(seed)
    small = np.stack(dropout_draws(key, K))
    big = np.stack(dropout_draws(key, K + extra))
    np.testing.assert_array_equal(small, big[:, :K])
