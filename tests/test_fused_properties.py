"""Property tests for the fused round engine's state algebra.

Runs under real ``hypothesis`` when installed, else under the deterministic
shim in tests/_hypothesis_fallback.py (registered by conftest).  Properties:

* Lyapunov queues stay non-negative under any recursion of
  ``lyapunov.queue_update`` (numpy and jnp backends agree);
* ``ClientCost.tau_residual`` is monotone in τ_max (the In1 budget can only
  grow with the latency budget);
* the fused carry round-trips through tree flatten/unflatten unchanged — the
  structural invariant ``lax.scan`` relies on.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.fl.fused_round import FusedCarry, RoundAux, RoundXs
from repro.wireless.cost import ClientCost
from repro.wireless.lyapunov import queue_update
from repro.wireless.params import WirelessParams


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 32), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.1))
def test_queue_update_nonnegative_recursion(K, seed, E_add):
    rng = np.random.default_rng(seed)
    Q = rng.uniform(0, 1.0, K)
    for _ in range(5):
        used = rng.uniform(0, 0.5, K) * rng.integers(0, 2, K)
        Qn = np.asarray(queue_update(Q, used, E_add))
        assert (Qn >= 0).all()
        np.testing.assert_allclose(Qn, np.maximum(Q - (E_add - used), 0))
        # backend-agnostic: jnp recursion matches numpy to f32 tolerance
        Qj = queue_update(jnp.asarray(Q, jnp.float32),
                          jnp.asarray(used, jnp.float32), E_add)
        np.testing.assert_allclose(np.asarray(Qj), Qn, rtol=1e-5, atol=1e-6)
        Q = Qn


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 16), st.integers(0, 2 ** 31 - 1),
       st.floats(1e-4, 0.05), st.floats(0.0, 0.05))
def test_tau_residual_monotone_in_tau_max(K, seed, tau_lo, tau_gap):
    rng = np.random.default_rng(seed)
    cost = ClientCost(gamma_bits=rng.uniform(1e5, 1e6, K),
                      tau_cmp=rng.uniform(0, 0.02, K),
                      e_cmp=rng.uniform(0, 0.01, K))
    lo = cost.tau_residual(WirelessParams(tau_max=tau_lo))
    hi = cost.tau_residual(WirelessParams(tau_max=tau_lo + tau_gap))
    assert (hi >= lo).all()
    np.testing.assert_allclose(hi - lo, tau_gap, atol=1e-12)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 8), st.integers(1, 3), st.integers(0, 2 ** 31 - 1))
def test_fused_carry_tree_roundtrip(K, M, seed):
    rng = np.random.default_rng(seed)
    mods = [f"m{i}" for i in range(M)]
    carry = FusedCarry(
        params={m: {"w": jnp.asarray(rng.normal(size=(4, 2)), jnp.float32),
                    "b": jnp.asarray(rng.normal(size=(2,)), jnp.float32)}
                for m in mods},
        policy={"warm_a": jnp.asarray(rng.integers(0, 2, K), bool)},
        Q=jnp.asarray(rng.uniform(0, 1, K), jnp.float32),
        spent=jnp.asarray(rng.uniform(0, 1, K), jnp.float32),
        zeta=jnp.asarray(rng.uniform(0, 2, M), jnp.float32),
        delta=jnp.asarray(rng.uniform(0, 1, (M, K)), jnp.float32),
        model_dist=jnp.asarray(rng.uniform(0, 1, K), jnp.float32))
    leaves, treedef = jax.tree_util.tree_flatten(carry)
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, FusedCarry)
    assert jax.tree_util.tree_structure(rebuilt) == treedef
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(rebuilt)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identity-mapping through jax.tree.map preserves the NamedTuple type
    mapped = jax.tree.map(lambda x: x, carry)
    assert isinstance(mapped, FusedCarry)


def test_round_pytrees_scan_compatible():
    """RoundXs/RoundAux slice along a leading axis like lax.scan needs."""
    K, R = 4, 3
    xs = RoundXs(h=jnp.zeros((R, K)), draw_seed=jnp.zeros(R, jnp.uint32),
                 client_seeds=jnp.zeros((R, K), jnp.uint32))
    x0 = jax.tree.map(lambda x: x[0], xs)
    assert isinstance(x0, RoundXs) and x0.h.shape == (K,)
    aux = RoundAux(a=jnp.zeros(K, bool), ok=jnp.zeros(K, bool),
                   J=jnp.float32(0), weights={"m": jnp.zeros(K)},
                   energy_total=jnp.float32(0))
    stacked = jax.tree.map(lambda x: jnp.stack([x, x]), aux)
    assert isinstance(stacked, RoundAux)
    assert stacked.weights["m"].shape == (2, K)
