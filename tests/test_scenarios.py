"""Scenario library + scan_scenario_grid contract.

Builder properties (split laws, per-modality ω_m, corruption axes), spec
validation, grid stacking, and the sweep contracts: ``scan_v_grid`` is now a
thin ``scan_scenario_grid({"V": ...})`` wrapper and must stay bit-exact with
it, and the sharded ``("scenario",)`` sweep must be bit-exact with the
single-device vmap (4-device case in a subprocess, grid size deliberately
not divisible by the device count so padding is exercised).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.partition import missing_counts
from repro.data.scenarios import (DATASET_SHAPES, ScenarioSpec,
                                  build_scenario, stack_scenarios)
from repro.wireless.params import WirelessParams

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

PARAMS = WirelessParams(K=6, B_max=6e6, E_add=2e-4)
GEOM = dict(dataset="iemocap", K=6, n_per_client=4, n_test=16)


def _leaves_equal(a, b) -> bool:
    """Bit-exact up to NaN==NaN (metrics rows are NaN off the eval cadence;
    equal_nan chokes on bool/int leaves, hence the dtype split)."""
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype.kind == "f":
        return np.array_equal(a, b, equal_nan=True)
    return np.array_equal(a, b)


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------
def test_spec_validation_errors():
    with pytest.raises(ValueError):
        ScenarioSpec(dataset="mosei")
    with pytest.raises(ValueError):
        ScenarioSpec(split="pathological")
    with pytest.raises(ValueError):
        ScenarioSpec(split="dirichlet", alpha=0.0)
    with pytest.raises(ValueError):
        ScenarioSpec(split="natural", n_groups=0)
    with pytest.raises(ValueError):
        ScenarioSpec(erasure_rate=1.5)
    with pytest.raises(ValueError):
        ScenarioSpec(test_missing="video")
    with pytest.raises(ValueError):
        ScenarioSpec(omega=1.0)                 # normalize-at-construction


def test_spec_normalizes_omega_snr_to_tuples():
    s = ScenarioSpec(omega={"text": 0.4}, snr=2.0)
    assert s.omega == (0.0, 0.4)                # sorted: (audio, text)
    assert s.snr == (2.0, 2.0)
    assert s.modalities == ("audio", "text")
    assert "om=0/0.4" in s.label()
    assert ScenarioSpec(name="zed").label() == "zed"


# ---------------------------------------------------------------------------
# builder properties
# ---------------------------------------------------------------------------
def test_build_scenario_ownership_matches_missing_counts():
    for omega in (0.0, 0.3, 0.6, (0.6, 0.2)):
        spec = ScenarioSpec(omega=omega, **GEOM)
        store, tf, tl = build_scenario(spec, PARAMS)
        counts = missing_counts(spec.K, spec.omega)
        for i, m in enumerate(spec.modalities):
            has = np.asarray(store.has_modality[m])
            assert int((~has).sum()) == counts[i], (omega, m)
        has_all = np.stack([np.asarray(store.has_modality[m])
                            for m in spec.modalities])
        assert has_all.any(axis=0).all()
        # cost vectors filled for owners (Eqs. 15-18), zero otherwise
        assert (np.asarray(store.gamma_bits)[has_all.any(axis=0)] > 0).all()


def test_build_scenario_shapes_and_labels():
    spec = ScenarioSpec(**GEOM)
    store, tf, tl = build_scenario(spec, PARAMS)
    shapes, C = DATASET_SHAPES["iemocap"]
    for m, shape in shapes.items():
        assert np.asarray(store.features[m]).shape == (6, 4) + shape
        assert tf[m].shape == (16,) + shape
    y = np.asarray(store.labels)
    assert y.shape == (6, 4) and y.min() >= 0 and y.max() < C
    assert tl.shape == (16,) and tl.max() < C


def test_dirichlet_split_skews_labels():
    C = DATASET_SHAPES["iemocap"][1]

    def mean_client_label_diversity(split, alpha):
        spec = ScenarioSpec(split=split, alpha=alpha, omega=0.0,
                            dataset="iemocap", K=8, n_per_client=64,
                            n_test=8, seed=1)
        y = np.asarray(build_scenario(spec, PARAMS)[0].labels)
        return np.mean([len(set(r.tolist())) for r in y])

    iid = mean_client_label_diversity("iid", 0.5)
    skew = mean_client_label_diversity("dirichlet", 0.1)
    assert iid > 0.8 * C                        # 64 draws cover ~all classes
    assert skew < 0.6 * iid                     # α=0.1 collapses per-client


def test_natural_split_group_structure():
    """Clients within a natural group share a feature offset: within-group
    client-mean distances must be far below cross-group ones."""
    spec = ScenarioSpec(split="natural", alpha=100.0, n_groups=2,
                        group_sigma=4.0, omega=0.0, dataset="iemocap",
                        K=8, n_per_client=16, n_test=8, seed=2)
    x = np.asarray(build_scenario(spec, PARAMS)[0].features["audio"])
    mu = x.mean(axis=1).reshape(8, -1)          # [K, d] client means
    groups = (np.arange(8) * 2) // 8
    d = np.linalg.norm(mu[:, None] - mu[None], axis=-1)
    within = d[groups[:, None] == groups[None]].mean()
    across = d[groups[:, None] != groups[None]].mean()
    assert across > 2 * within


def test_erasure_zeroes_sample_blocks():
    spec = ScenarioSpec(erasure_rate=0.5, omega=0.0, dataset="iemocap",
                        K=8, n_per_client=32, n_test=8, seed=3)
    store = build_scenario(spec, PARAMS)[0]
    # an erased (client, sample) slot is zero across the whole block, and
    # the realized rate is near 0.5 for every modality (same mask per spec
    # draw order, drawn per modality)
    for m in spec.modalities:
        x = np.asarray(store.features[m]).reshape(8, 32, -1)
        dead = ~np.abs(x).sum(-1).astype(bool)
        assert 0.3 < dead.mean() < 0.7, (m, dead.mean())


def test_test_missing_zeroes_only_that_test_modality():
    spec = ScenarioSpec(test_missing="text", omega=0.0, **GEOM)
    store, tf, tl = build_scenario(spec, PARAMS)
    assert not tf["text"].any()
    assert tf["audio"].any()
    # clients' train features keep both modalities — it's deployment-time
    assert np.asarray(store.features["text"]).any()


def test_features_carry_class_signal():
    spec = ScenarioSpec(omega=0.0, snr=2.0, dataset="iemocap", K=4,
                        n_per_client=128, n_test=8, seed=4)
    store = build_scenario(spec, PARAMS)[0]
    x = np.asarray(store.features["audio"]).reshape(4 * 128, -1)
    y = np.asarray(store.labels).reshape(-1)
    C = spec.n_classes
    mus = np.stack([x[y == c].mean(axis=0) for c in range(C)
                    if (y == c).sum() > 5])
    spread = np.linalg.norm(mus - mus.mean(0), axis=-1)
    assert spread.min() > 1.0                   # not pure noise


# ---------------------------------------------------------------------------
# stacking
# ---------------------------------------------------------------------------
def test_stack_scenarios_shapes_and_geometry_check():
    specs = [ScenarioSpec(omega=w, seed=i, **GEOM)
             for i, w in enumerate((0.0, 0.3, 0.6))]
    grid = stack_scenarios(specs, PARAMS)
    assert grid.n == 3
    assert np.asarray(grid.stores.labels).shape == (3, 6, 4)
    assert grid.test_labels.shape == (3, 16)
    assert grid.overrides["V"].shape == (3,)
    assert grid.overrides["has"].shape == (3, 2, 6)
    assert grid.overrides["tau_cmp"].shape == (3, 6)
    row = grid.store_row(1)
    assert np.asarray(row.labels).shape == (6, 4)

    with pytest.raises(ValueError):
        stack_scenarios([], PARAMS)
    with pytest.raises(ValueError):
        stack_scenarios([specs[0],
                         ScenarioSpec(dataset="iemocap", K=8,
                                      n_per_client=4, n_test=16)], PARAMS)


# ---------------------------------------------------------------------------
# sweep contracts (single device in-process; 4-device in a subprocess)
# ---------------------------------------------------------------------------
def _tiny_engine_and_xs(rounds=3, eval_every=2):
    from repro.fl.client import PaperModelAdapter
    from repro.fl.fused_round import FusedRoundEngine, draw_population_xs
    from repro.wireless.channel import Channel
    from repro.wireless.policies import JCSBAPolicy

    specs = [ScenarioSpec(split=s, omega=w, seed=i, **GEOM)
             for i, (s, w) in enumerate((("iid", 0.0), ("dirichlet", 0.3),
                                         ("iid", 0.6)))]
    grid = stack_scenarios(specs, PARAMS)
    eng = FusedRoundEngine.from_store(grid.store_row(0), PARAMS,
                                      JCSBAPolicy(6, max_cohort=3),
                                      PaperModelAdapter("iemocap"), seed=0)
    rng = np.random.default_rng(1)
    xs = draw_population_xs(Channel(PARAMS, rng), rng, 6, rounds,
                            eval_every=eval_every, include_final=True)
    return grid, eng, xs


def test_scenario_grid_runs_and_metrics_finite():
    import jax

    grid, eng, xs = _tiny_engine_and_xs()
    carries, auxs = jax.block_until_ready(eng.scan_scenario_grid(
        grid.overrides, eng.fresh_carry(), xs, stores=grid.stores,
        test_sets=(grid.test_features, grid.test_labels)))
    acc = np.asarray(auxs.metrics["multimodal"])    # [S, R]
    assert acc.shape == (3, 3)
    emask = np.asarray(auxs.eval_mask)
    assert np.isfinite(acc[emask]).all()
    assert (acc[emask] >= 0).all() and (acc[emask] <= 1).all()
    assert np.isfinite(np.asarray(carries.spent)).all()
    # the grid rows genuinely differ (ω axis changes participation physics)
    ok = np.asarray(auxs.ok)                        # [S, R, K]
    assert len({tuple(ok[s].sum(-1)) for s in range(3)}) > 1


def test_scan_v_grid_delegates_bit_exact():
    """scan_v_grid is now scan_scenario_grid({"V": ...}) — same leaves,
    bit for bit, on the single-device path."""
    import jax

    _, eng, xs = _tiny_engine_and_xs()
    V = [0.1, 1.0, 10.0]
    a = jax.block_until_ready(eng.scan_v_grid(V, eng.fresh_carry(), xs))
    b = jax.block_until_ready(eng.scan_scenario_grid(
        {"V": np.asarray(V)}, eng.fresh_carry(), xs))
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert _leaves_equal(la, lb)


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax

from repro.data.scenarios import ScenarioSpec, stack_scenarios
from repro.fl.client import PaperModelAdapter
from repro.fl.fused_round import FusedRoundEngine, draw_population_xs
from repro.launch.mesh import make_sweep_mesh
from repro.wireless.channel import Channel
from repro.wireless.params import WirelessParams
from repro.wireless.policies import JCSBAPolicy

params = WirelessParams(K=6, B_max=6e6, E_add=2e-4)
geom = dict(dataset="iemocap", K=6, n_per_client=4, n_test=16)
specs = [ScenarioSpec(split=s, omega=w, noise_sigma=ns, seed=i, **geom)
         for i, (s, w, ns) in enumerate(
             (("iid", 0.0, 0.0), ("dirichlet", 0.3, 0.0),
              ("iid", 0.6, 0.5)))]          # 3 rows on 4 devices -> padding
grid = stack_scenarios(specs, params)
eng = FusedRoundEngine.from_store(grid.store_row(0), params,
                                  JCSBAPolicy(6, max_cohort=3),
                                  PaperModelAdapter("iemocap"), seed=0)
rng = np.random.default_rng(1)
xs = draw_population_xs(Channel(params, rng), rng, 6, 3, eval_every=2,
                        include_final=True)
kw = dict(stores=grid.stores,
          test_sets=(grid.test_features, grid.test_labels))
carry = eng.fresh_carry()

single = eng.scan_scenario_grid(grid.overrides, carry, xs, mesh=None, **kw)
mesh = make_sweep_mesh()
assert mesh is not None and int(mesh.devices.size) == 4, mesh
shard = eng.scan_scenario_grid(grid.overrides, carry, xs, mesh=mesh, **kw)

bit_exact = True
for a, b in zip(jax.tree.leaves(single), jax.tree.leaves(shard)):
    a, b = np.asarray(a), np.asarray(b)
    assert a.shape == b.shape, (a.shape, b.shape)
    eq = (np.array_equal(a, b, equal_nan=True) if a.dtype.kind == "f"
          else np.array_equal(a, b))
    if not eq:
        bit_exact = False
        np.testing.assert_allclose(a, b, rtol=2e-6, atol=1e-7)
acc = np.asarray(shard[1].metrics["multimodal"])   # [S, R]
emask = np.asarray(shard[1].eval_mask)             # [S, R]
print(json.dumps({"ok": True, "devices": jax.device_count(),
                  "bit_exact": bit_exact, "n_S": int(acc.shape[0]),
                  "finite": bool(np.isfinite(acc[emask]).all())}))
"""


def test_scan_scenario_grid_sharded_matches_single_device():
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 4
    assert out["n_S"] == 3
    assert out["bit_exact"]
    assert out["finite"]
