"""Batched JCSBA solver: jax/numpy parity, legacy cross-checks, properties.

Three layers of evidence that the fused solver is the same algorithm:
  * float32 jitted backend == float64 numpy mirror on the same random bits
    (bit-identical schedules, allocations to ~Hz);
  * batched allocation == legacy scalar ``bandwidth.allocate`` KKT point;
  * every feasible allocation satisfies the latency constraint (In1) and the
    bandwidth budget — as a property over random instances.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.aggregation import unified_weights
from repro.core.convergence import BoundState, objective_batched
from repro.wireless import bandwidth as bw
from repro.wireless import cost as wcost
from repro.wireless.channel import Channel, uplink_rate
from repro.wireless.params import MODALITY_PROFILES, WirelessParams
from repro.wireless.schedulers import ScheduleContext, make_scheduler
from repro.wireless.solver import (SolverHyper, build_solver_data,
                                   solve_round, solve_round_np)
from repro.wireless.solver import ref as sref

HP = SolverHyper()
HP_SMALL = SolverHyper(S=8, G=3)


def _data(K=6, seed=0, tau_max=None, dataset="crema_d", V=1.0):
    params = WirelessParams(K=K, **({} if tau_max is None
                                    else {"tau_max": tau_max}))
    rng = np.random.default_rng(seed)
    prof = MODALITY_PROFILES[dataset]
    mods = ([("audio", "image"), ("audio",), ("image",)] * (K // 3 + 1))[:K]
    sizes = [50] * K
    cc = wcost.client_costs(sizes, mods, prof, params)
    ch = Channel(params, rng)
    w = unified_weights(sizes, mods, ["audio", "image"])
    bound = BoundState(K, ["audio", "image"], mods, w, sizes)
    # perturb the trackers so the bound term is not at its symmetric init
    for m in bound.mods:
        bound.zeta[m] = float(rng.uniform(0.5, 2.0))
        bound.delta[m] = rng.uniform(0.1, 0.6, K)
    data = build_solver_data(ch.draw(), rng.uniform(0, 0.01, K), cc, params,
                             bound, V)
    return data, bound, cc, params, mods, rng


def _rand_pop(data, rng, P=12):
    K = len(data["Q"])
    return rng.integers(0, 2, (P, K)).astype(bool)


# ---------------------------------------------------------------------------
# batched allocation: jax vs numpy reference vs legacy scalar
# ---------------------------------------------------------------------------
def _allocate_both(data, A, hp=HP):
    from repro.wireless.solver import jaxsolver as sjax
    bmin, ok = sref.bmin_np(data["gamma"], data["h"], data["tau_rem"],
                            data["B_max"], data["p_tx"], data["N0"], hp)
    Bn, fn = sref.allocate_np(A, bmin, ok, data["Q"], data["gamma"],
                              data["h"], data["B_max"], data["p_tx"],
                              data["N0"], hp)
    d32 = sjax.to_device(data)
    bmin_j, ok_j = sjax._bmin(d32["gamma"], d32["h"], d32["tau_rem"],
                              d32["B_max"], d32["p_tx"], d32["N0"], hp)
    Bj, fj = sjax.allocate_batch(A, bmin_j, ok_j, d32["Q"], d32["gamma"],
                                 d32["h"], d32["B_max"], d32["p_tx"],
                                 d32["N0"], hp)
    return (Bn, fn), (np.asarray(Bj, np.float64), np.asarray(fj))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_allocate_parity_jax_vs_np(seed):
    data, *_ = _data(K=6, seed=seed)
    rng = np.random.default_rng(seed + 100)
    A = _rand_pop(data, rng)
    (Bn, fn), (Bj, fj) = _allocate_both(data, A)
    assert np.array_equal(fn, fj)
    assert np.allclose(Bj, Bn, rtol=1e-3, atol=2.0)


def test_allocate_infeasible_is_mask_not_none():
    # tiny latency budget: nobody can make the deadline -> every non-empty
    # candidate infeasible, B identically zero, empty candidate feasible
    data, *_ = _data(K=6, seed=3, tau_max=1e-6)
    A = np.vstack([np.eye(6, dtype=bool), np.zeros((1, 6), bool)])
    (Bn, fn), (Bj, fj) = _allocate_both(data, A)
    assert not fn[:6].any() and fn[6]
    assert np.array_equal(fn, fj)
    assert (Bn == 0).all() and (Bj == 0).all()


@pytest.mark.parametrize("seed", [0, 1, 4])
def test_allocate_matches_legacy_scalar(seed):
    """Single-candidate rows of the batched solve land on the same KKT point
    as the sequential bandwidth.allocate."""
    data, _, cc, params, _, rng = _data(K=6, seed=seed)
    checked = 0
    for _ in range(6):
        a = rng.integers(0, 2, 6).astype(bool)
        if not a.any():
            continue
        part = np.flatnonzero(a)
        Bl = bw.allocate(data["Q"][part], data["gamma"][part],
                         data["h"][part], data["tau_rem"][part], params)
        (Bn, fn), _ = _allocate_both(data, a[None])
        if Bl is None:
            assert not fn[0]
            continue
        assert fn[0]
        assert np.allclose(Bn[0][part], Bl, rtol=2e-3, atol=5.0)
        checked += 1
    assert checked >= 2


# ---------------------------------------------------------------------------
# Theorem-1 bound: scalar vs batched-np vs batched-jnp
# ---------------------------------------------------------------------------
def test_bound_objective_three_way_parity():
    data, bound, *_ = _data(K=6, seed=5)
    rng = np.random.default_rng(7)
    A = _rand_pop(data, rng, P=16)
    want = np.array([bound.objective(a.astype(float)) for a in A])
    got_np = sref.bound_objective_np(A, data["zeta2"], data["delta2"],
                                     data["wbar"], data["has"], data["D"],
                                     data["eta"], data["rho"])
    got_j = np.asarray(objective_batched(
        A.astype(np.float32), data["zeta2"].astype(np.float32),
        data["delta2"].astype(np.float32), data["wbar"].astype(np.float32),
        data["has"], data["D"].astype(np.float32),
        data["eta"], data["rho"]))
    assert np.allclose(got_np, want, rtol=1e-10, atol=1e-12)
    assert np.allclose(got_j, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# full solve + scheduler decisions: jax vs np on the same draws
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 11])
def test_immune_solve_parity(seed):
    data, *_ = _data(K=6, seed=seed)
    seeds = np.zeros((2, 6), bool)
    aj, Jj, Bj = solve_round(data, seeds, 1234 + seed, HP_SMALL)
    an, Jn, Bn = solve_round_np(data, seeds, 1234 + seed, HP_SMALL)
    assert np.array_equal(aj, an)
    assert Jj == pytest.approx(Jn, rel=1e-4, abs=1e-6)
    assert np.allclose(Bj, Bn, rtol=1e-3, atol=2.0)


def test_scheduler_decision_parity_across_rounds():
    """Per-round ScheduleDecision parity: solver='jax' and solver='np' track
    the same schedule/allocation over multiple rounds (warm starts, rng
    stream and Lyapunov-queue coupling included)."""
    decs = {}
    for solver in ("jax", "np"):
        data_rng = np.random.default_rng(0)
        _, bound, cc, params, mods, _ = _data(K=6, seed=0)
        sched = make_scheduler("jcsba", np.random.default_rng(42),
                               solver=solver)
        out = []
        for t in range(3):
            ctx = ScheduleContext(
                h=10 ** data_rng.uniform(-7, -4, 6),
                Q=data_rng.uniform(0, 0.02, 6), cost=cc, params=params,
                bound=bound, round_idx=t, model_dist=np.zeros(6),
                client_modalities=mods)
            out.append(sched.schedule(ctx))
        decs[solver] = out
    for dj, dn in zip(decs["jax"], decs["np"]):
        assert np.array_equal(dj.a, dn.a)
        assert np.allclose(dj.B, dn.B, rtol=1e-3, atol=2.0)
        assert dj.objective == pytest.approx(dn.objective, rel=1e-4,
                                             abs=1e-6)


def test_scheduler_seq_backend_still_works():
    _, bound, cc, params, mods, rng = _data(K=6, seed=1)
    sched = make_scheduler("jcsba", np.random.default_rng(0), solver="seq")
    ctx = ScheduleContext(h=10 ** rng.uniform(-7, -4, 6),
                          Q=np.zeros(6), cost=cc, params=params, bound=bound,
                          round_idx=0, model_dist=np.zeros(6),
                          client_modalities=mods)
    dec = sched.schedule(ctx)
    assert dec.a.shape == (6,) and np.isfinite(dec.objective)


def test_unknown_solver_backend_rejected():
    with pytest.raises(ValueError):
        make_scheduler("jcsba", np.random.default_rng(0), solver="torch")


# ---------------------------------------------------------------------------
# properties: feasible allocations respect In1 and the bandwidth budget
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_feasible_allocations_meet_constraints(seed):
    rng = np.random.default_rng(seed)
    K = int(rng.integers(2, 8))
    params = WirelessParams(K=K)
    data = {
        "Q": rng.uniform(0.0, 2.0, K),
        "gamma": rng.uniform(3e5, 1.2e6, K),
        "h": 10 ** rng.uniform(-7, -4, K),
        "tau_rem": rng.uniform(0.004, 0.0095, K),
        "B_max": params.B_max, "p_tx": params.p_tx, "N0": params.N0,
    }
    A = rng.integers(0, 2, (10, K)).astype(bool)
    bmin, ok = sref.bmin_np(data["gamma"], data["h"], data["tau_rem"],
                            data["B_max"], data["p_tx"], data["N0"], HP)
    B, feas = sref.allocate_np(A, bmin, ok, data["Q"], data["gamma"],
                               data["h"], data["B_max"], data["p_tx"],
                               data["N0"], HP)
    for p in range(len(A)):
        a = A[p]
        if not feas[p]:
            # genuinely infeasible: some client can never meet the deadline,
            # or the minimum bandwidths alone blow the budget (Eq. 42)
            bl = [bw.b_min(data["gamma"][i], data["h"][i],
                           data["tau_rem"][i], params)
                  for i in np.flatnonzero(a)]
            assert any(b is None for b in bl) or sum(bl) > params.B_max
            assert (B[p] == 0).all()
            continue
        assert (B[p][~a] == 0).all()
        assert (B[p][a] > 0).all() or not a.any()
        assert B[p].sum() <= params.B_max * (1 + 1e-6)
        if a.any():
            part = np.flatnonzero(a)
            r = uplink_rate(B[p][part], data["h"][part], params)
            tau_com = data["gamma"][part] / r
            assert np.all(tau_com <= data["tau_rem"][part] * (1 + 1e-3))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_property_jax_feasible_allocations_meet_constraints(seed):
    """Same property on the float32 jitted path — the BMIN_SAFETY margin must
    absorb float32 rounding so allocations stay strictly feasible."""
    from repro.wireless.solver import jaxsolver as sjax
    rng = np.random.default_rng(seed)
    K = 5
    params = WirelessParams(K=K)
    data = {
        "Q": rng.uniform(0.0, 2.0, K),
        "gamma": rng.uniform(3e5, 1.2e6, K),
        "h": 10 ** rng.uniform(-7, -4, K),
        "tau_rem": rng.uniform(0.004, 0.0095, K),
        "B_max": params.B_max, "p_tx": params.p_tx, "N0": params.N0,
    }
    A = rng.integers(0, 2, (8, K)).astype(bool)
    d32 = sjax.to_device(data)
    bmin, ok = sjax._bmin(d32["gamma"], d32["h"], d32["tau_rem"],
                          d32["B_max"], d32["p_tx"], d32["N0"], HP)
    B, feas = sjax.allocate_batch(A, bmin, ok, d32["Q"], d32["gamma"],
                                  d32["h"], d32["B_max"], d32["p_tx"],
                                  d32["N0"], HP)
    B, feas = np.asarray(B, np.float64), np.asarray(feas)
    for p in range(len(A)):
        a = A[p]
        if not feas[p] or not a.any():
            continue
        part = np.flatnonzero(a)
        assert B[p].sum() <= params.B_max * (1 + 1e-5)
        r = uplink_rate(B[p][part], data["h"][part], params)
        tau_com = data["gamma"][part] / r
        # strict host-side feasibility, as checked by the FL runtime
        assert np.all(tau_com <= data["tau_rem"][part] + 1e-12)


def test_solver_objective_accounts_empty_schedule():
    """The all-zeros antibody is always seeded, so J* is finite even when
    every non-empty candidate is infeasible."""
    data, *_ = _data(K=6, seed=9, tau_max=1e-6)
    seeds = np.zeros((2, 6), bool)
    a, J, B = solve_round(data, seeds, 7, HP_SMALL)
    assert not a.any()
    assert np.isfinite(J)
    assert (B == 0).all()
