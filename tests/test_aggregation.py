"""Per-modality aggregation (Eqs. 9-12): unbiasedness + weight properties."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import aggregation as agg


MODS = ["audio", "image"]


def _mk_clients(rng, K):
    """Random client modality sets (each keeps >= 1 modality) + data sizes."""
    mods = []
    for _ in range(K):
        pick = rng.integers(1, 4)  # 1=audio, 2=image, 3=both
        mods.append(tuple(m for i, m in enumerate(MODS) if pick >> i & 1))
    sizes = rng.integers(10, 100, K).tolist()
    return mods, sizes


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 10), st.integers(0, 2 ** 31 - 1))
def test_property_unified_weights_sum_to_one(K, seed):
    rng = np.random.default_rng(seed)
    mods, sizes = _mk_clients(rng, K)
    w = agg.unified_weights(sizes, mods, MODS)
    for m in MODS:
        s = w[m].sum()
        assert s == 0.0 or abs(s - 1.0) < 1e-9
        for k in range(K):
            if m not in mods[k]:
                assert w[m][k] == 0.0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 8), st.integers(0, 2 ** 31 - 1))
def test_property_participated_weights_renormalise(K, seed):
    rng = np.random.default_rng(seed)
    mods, sizes = _mk_clients(rng, K)
    part = [k for k in range(K) if rng.random() < 0.5]
    w = agg.participated_weights(sizes, mods, part, MODS)
    for m in MODS:
        contributors = [k for k in part if m in mods[k]]
        if contributors:
            assert abs(w[m].sum() - 1.0) < 1e-9
        else:
            assert w[m].sum() == 0.0


def test_full_participation_unbiased():
    """Eq. 10: full participation reproduces the unified-weight aggregate."""
    rng = np.random.default_rng(0)
    K = 5
    mods, sizes = _mk_clients(rng, K)
    g = {m: {"w": jnp.zeros((3,))} for m in MODS}
    client_params = []
    for k in range(K):
        client_params.append({m: {"w": jnp.asarray(rng.normal(size=3),
                                                   jnp.float32)}
                              for m in mods[k]})
    w_full = agg.participated_weights(sizes, mods, range(K), MODS)
    w_bar = agg.unified_weights(sizes, mods, MODS)
    out1 = agg.aggregate(g, client_params, w_full)
    out2 = agg.aggregate(g, client_params, w_bar)
    for m in MODS:
        np.testing.assert_allclose(out1[m]["w"], out2[m]["w"], rtol=1e-6)


def test_unseen_modality_keeps_global():
    g = {"audio": {"w": jnp.ones((2,))}, "image": {"w": 2 * jnp.ones((2,))}}
    cp = [{"audio": {"w": jnp.zeros((2,))}}, None]
    w = agg.weights_from_uploads([10, 10], cp, MODS)
    out = agg.aggregate(g, cp, w)
    np.testing.assert_allclose(out["image"]["w"], 2 * np.ones(2))   # unchanged
    np.testing.assert_allclose(out["audio"]["w"], np.zeros(2))


def test_weights_from_uploads_handles_dropout():
    """A client that dropped a modality must not dilute that modality's
    aggregate (the convex-combination property)."""
    cp = [{"audio": 1}, {"audio": 1, "image": 1}, None]
    w = agg.weights_from_uploads([10, 30, 60], cp, MODS)
    assert abs(w["audio"].sum() - 1.0) < 1e-9
    assert abs(w["image"].sum() - 1.0) < 1e-9
    assert w["image"][0] == 0.0 and w["image"][2] == 0.0
    assert w["image"][1] == 1.0


def test_aggregate_gradients_matches_manual():
    rng = np.random.default_rng(0)
    g1 = {"audio": {"w": jnp.asarray(rng.normal(size=3), jnp.float32)}}
    g2 = {"audio": {"w": jnp.asarray(rng.normal(size=3), jnp.float32)}}
    w = {"audio": np.array([0.25, 0.75])}
    out = agg.aggregate_gradients([g1, g2], w)
    np.testing.assert_allclose(
        out["audio"]["w"], 0.25 * g1["audio"]["w"] + 0.75 * g2["audio"]["w"],
        rtol=1e-6)
