"""Deterministic fallback shim for ``hypothesis``.

CI installs the real hypothesis (see requirements.txt); air-gapped or minimal
environments may not have it, and the suite must still collect and pass there.
``install()`` registers a tiny stand-in module under ``sys.modules`` *only if*
the real package is unavailable.  The shim supports exactly the API surface the
test-suite uses — ``@settings(max_examples=..., deadline=...)``, ``@given``,
``st.integers`` and ``st.sampled_from`` — and replays a fixed, deterministic
example set (boundary values first, then seeded pseudo-random draws) instead
of doing real property-based search.
"""
from __future__ import annotations

import random
import sys
import types


class _Strategy:
    """A value generator with deterministic indexed examples."""

    def __init__(self, example_fn):
        self._example_fn = example_fn

    def example_at(self, i: int, rng: random.Random):
        return self._example_fn(i, rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    def gen(i, rng):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.randint(min_value, max_value)

    return _Strategy(gen)


def sampled_from(elements) -> _Strategy:
    elements = list(elements)

    def gen(i, rng):
        if i < len(elements):
            return elements[i]
        return rng.choice(elements)

    return _Strategy(gen)


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    def gen(i, rng):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.uniform(min_value, max_value)

    return _Strategy(gen)


def booleans() -> _Strategy:
    return sampled_from([False, True])


def given(*strategies):
    def deco(fn):
        def wrapper():
            n = getattr(wrapper, "_max_examples", 10)
            rng = random.Random(fn.__qualname__)  # deterministic per test
            for i in range(n):
                vals = tuple(s.example_at(i, rng) for s in strategies)
                fn(*vals)

        # NOTE: deliberately no functools.wraps — pytest must see a
        # zero-argument test, not the wrapped signature (it would try to
        # resolve the strategy parameters as fixtures).
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._max_examples = 10
        return wrapper

    return deco


def settings(max_examples: int = 10, deadline=None, **_kw):
    def deco(fn):
        if hasattr(fn, "_max_examples"):
            fn._max_examples = max_examples
        return fn

    return deco


def install() -> None:
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401  (real package wins when available)
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.sampled_from = sampled_from
    st.floats = floats
    st.booleans = booleans
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    mod.__is_fallback_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
