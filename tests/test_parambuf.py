"""Flat donated param buffers (launch/parambuf): pack/unpack bit-exactness
per architecture, mixed-dtype layouts, in-place donated swap semantics, and
the flat checkpoint layout round-tripping against the pytree layout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch import parambuf, steps


def _tree_equal_bits(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.shape == y.shape and x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_pack_unpack_roundtrip(name):
    cfg = ARCHS[name].reduced()
    params = steps.init_fn(cfg)(jax.random.key(0))
    spec = parambuf.spec_of(params)
    bufs = parambuf.pack(params, spec)
    # reduced configs are all-float32: one buffer, total size = param count
    n_leaves = len(jax.tree.leaves(params))
    assert sum(n for _, n in spec.sizes) == sum(
        int(np.prod(x.shape)) if x.ndim else 1
        for x in jax.tree.leaves(params))
    assert len(spec.leaves) == n_leaves
    _tree_equal_bits(parambuf.unpack(bufs, spec), params)
    # host mirror shares the exact element layout
    np_bufs, np_spec = parambuf.pack_np(params)
    assert np_spec.leaves == spec.leaves and np_spec.sizes == spec.sizes
    for dt, n in spec.sizes:
        np.testing.assert_array_equal(np.asarray(bufs[dt]), np_bufs[dt])
    _tree_equal_bits(parambuf.unpack_np(np_bufs, np_spec), params)


def test_mixed_dtype_tree():
    tree = {
        "w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "step": jnp.int32(7),
        "half": jnp.ones((4,), jnp.bfloat16) * 1.5,
        "nested": [jnp.zeros((2,), jnp.float32),
                   jnp.array([1, 2], jnp.int32)],
    }
    spec = parambuf.spec_of(tree)
    assert spec.n_buffers == 3            # float32 / int32 / bfloat16
    sizes = dict(spec.sizes)
    assert sizes["float32"] == 8 and sizes["int32"] == 3
    assert sizes["bfloat16"] == 4
    out = parambuf.unpack(parambuf.pack(tree, spec), spec)
    _tree_equal_bits(out, tree)
    # spec is hashable/static (jit closes over it)
    hash(spec)


def test_spec_from_shape_structs():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    params = steps.init_fn(cfg)(jax.random.key(0))
    spec_live = parambuf.spec_of(params)
    spec_abs = parambuf.spec_of(jax.eval_shape(steps.init_fn(cfg),
                                               jax.random.key(0)))
    assert spec_abs.leaves == spec_live.leaves
    assert spec_abs.sizes == spec_live.sizes


def test_make_swap_in_place_and_stable():
    cfg = dataclasses.replace(ARCHS["qwen3-0.6b"].reduced())
    params = steps.init_fn(cfg)(jax.random.key(0))
    spec = parambuf.spec_of(params)
    bufs = parambuf.pack(params, spec)
    swap = parambuf.make_swap(spec)

    new_params = jax.tree.map(lambda x: x + 1.0, params)
    old = bufs
    bufs = swap(bufs, new_params)
    _tree_equal_bits(parambuf.unpack(bufs, spec), new_params)
    # donation consumed the old buffers: the swap reused the allocation
    # instead of copying into a fresh one
    for b in old.values():
        assert b.is_deleted()
    # repeated swaps retrace nothing
    for i in range(3):
        bufs = swap(bufs, jax.tree.map(lambda x: x * 0.5, new_params))
    if hasattr(swap, "_cache_size"):
        assert swap._cache_size() == 1


def test_flat_checkpoint_matches_tree_layout(tmp_path):
    from repro.checkpoint import (load_checkpoint, save_checkpoint,
                                  save_flat_checkpoint)
    cfg = ARCHS["mamba2-370m"].reduced()
    params = steps.init_fn(cfg)(jax.random.key(3))
    save_checkpoint(tmp_path / "tree", params, step=5)
    save_flat_checkpoint(tmp_path / "flat", params, step=5)
    t_tree, meta_t = load_checkpoint(tmp_path / "tree")
    t_flat, meta_f = load_checkpoint(tmp_path / "flat")
    assert meta_f["step"] == meta_t["step"] == 5
    assert meta_f.get("layout") == "flat"
    _tree_equal_bits(jax.tree.map(jnp.asarray, t_flat),
                     jax.tree.map(jnp.asarray, t_tree))
    _tree_equal_bits(jax.tree.map(jnp.asarray, t_flat), params)
