"""§Perf hillclimb levers must be semantics-preserving: chunked loss, remat
and sharding constraints change the schedule, never the math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.launch import steps


def _batch(cfg, rng, B=2, S=64):
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.arch_type == "vlm":
        b["patches"] = jnp.asarray(rng.normal(size=(B, 8, cfg.frontend_dims[0])),
                                   jnp.float32)
    return b


@pytest.mark.parametrize("name", ["qwen3-0.6b", "mamba2-370m",
                                  "llava-next-34b"])
def test_loss_chunk_preserves_loss(name):
    cfg = ARCHS[name].reduced()
    rng = np.random.default_rng(0)
    params = steps.init_fn(cfg)(jax.random.key(0))
    batch = _batch(cfg, rng)
    l0 = float(steps.make_loss_fn(cfg, attn_chunk=32)(params, batch))
    l1 = float(steps.make_loss_fn(cfg, attn_chunk=32,
                                  loss_chunk=16)(params, batch))
    assert l0 == pytest.approx(l1, rel=1e-5)


def test_remat_preserves_loss_and_grads():
    cfg = ARCHS["qwen3-0.6b"].reduced()
    rng = np.random.default_rng(0)
    params = steps.init_fn(cfg)(jax.random.key(0))
    batch = _batch(cfg, rng)
    f0 = steps.make_loss_fn(cfg, attn_chunk=32)
    f1 = steps.make_loss_fn(cfg, attn_chunk=32, remat=True)
    l0, g0 = jax.value_and_grad(f0)(params, batch)
    l1, g1 = jax.value_and_grad(f1)(params, batch)
    assert float(l0) == pytest.approx(float(l1), rel=1e-6)
    n0 = float(jnp.sqrt(sum(jnp.vdot(x, x).real for x in jax.tree.leaves(g0))))
    n1 = float(jnp.sqrt(sum(jnp.vdot(x, x).real for x in jax.tree.leaves(g1))))
    assert n0 == pytest.approx(n1, rel=1e-4)


def test_attn_chunk_invariance():
    """Flash-style chunk size is a pure scheduling knob."""
    from repro.models import transformer as T
    cfg = ARCHS["gemma3-12b"].reduced()
    rng = np.random.default_rng(0)
    params = steps.init_fn(cfg)(jax.random.key(0))
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 64)), jnp.int32)
    l8, _ = T.forward(params, tokens, cfg, attn_chunk=8)
    l32, _ = T.forward(params, tokens, cfg, attn_chunk=32)
    np.testing.assert_allclose(np.asarray(l8), np.asarray(l32),
                               rtol=2e-4, atol=2e-4)
