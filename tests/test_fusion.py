"""Decision-level fusion + unimodal loss (Eqs. 1-4) — unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fusion


def test_fuse_logits_is_mean_of_available():
    lg = {"a": jnp.ones((4, 3)), "b": 3 * jnp.ones((4, 3))}
    fused = fusion.fuse_logits(lg)
    np.testing.assert_allclose(fused, 2 * np.ones((4, 3)), rtol=1e-6)


def test_missing_modality_excluded_from_mean():
    lg = {"a": jnp.ones((2, 3)), "b": 5 * jnp.ones((2, 3))}
    avail = {"a": jnp.array(1.0), "b": jnp.array(0.0)}
    fused = fusion.fuse_logits(lg, avail)
    np.testing.assert_allclose(fused, np.ones((2, 3)), rtol=1e-6)


def test_broadcast_fusion_vlm_shape():
    # text [B,S,V] + vision [B,1,V] broadcasts over S (Eq. 1 at LM scale)
    text = jnp.zeros((2, 5, 7))
    vis = jnp.ones((2, 1, 7))
    fused = fusion.fuse_logits({"text": text, "vision": vis})
    assert fused.shape == (2, 5, 7)
    np.testing.assert_allclose(fused, 0.5, rtol=1e-6)


def test_multimodal_loss_decomposes():
    rng = np.random.default_rng(0)
    lg = {"a": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32),
          "b": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}
    y = jnp.asarray(rng.integers(0, 5, 8), jnp.int32)
    total, met = fusion.multimodal_loss(lg, y)
    np.testing.assert_allclose(float(total),
                               float(met["F"] + met["G_a"] + met["G_b"]),
                               rtol=1e-6)
    assert float(met["F"]) > 0 and float(met["G_a"]) > 0


def test_v_weights_scale_unimodal_terms():
    rng = np.random.default_rng(1)
    lg = {"a": jnp.asarray(rng.normal(size=(8, 5)), jnp.float32)}
    y = jnp.asarray(rng.integers(0, 5, 8), jnp.int32)
    _, m1 = fusion.multimodal_loss(lg, y, v_weights={"a": 1.0})
    _, m2 = fusion.multimodal_loss(lg, y, v_weights={"a": 2.0})
    np.testing.assert_allclose(2 * float(m1["G_a"]), float(m2["G_a"]),
                               rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(2, 8), st.integers(1, 3),
       st.integers(0, 2 ** 31 - 1))
def test_property_single_modality_fusion_identity(b, c, m, seed):
    """With one available modality, the fused loss equals that modality's CE."""
    rng = np.random.default_rng(seed)
    name = f"m{m}"
    lg = {name: jnp.asarray(rng.normal(size=(b, c)), jnp.float32)}
    y = jnp.asarray(rng.integers(0, c, b), jnp.int32)
    total, met = fusion.multimodal_loss(lg, y)
    np.testing.assert_allclose(float(met["F"]), float(met[f"G_{name}"]),
                               rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(2, 6), st.integers(0, 2 ** 31 - 1))
def test_property_fused_nll_at_least_best_modality_bound(b, c, seed):
    """CE values are finite and non-negative for random logits."""
    rng = np.random.default_rng(seed)
    lg = {"a": jnp.asarray(rng.normal(size=(b, c)), jnp.float32),
          "b": jnp.asarray(rng.normal(size=(b, c)), jnp.float32)}
    y = jnp.asarray(rng.integers(0, c, b), jnp.int32)
    total, met = fusion.multimodal_loss(lg, y)
    assert np.isfinite(float(total))
    for k in ("F", "G_a", "G_b"):
        assert float(met[k]) >= 0.0


def test_unimodal_logits_reused_not_recomputed():
    """The 'no extra compute' claim (§II): multimodal_loss consumes the
    already-computed unimodal logits — one forward pass serves F and all
    G_m; the metrics expose every term."""
    lg = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((2, 3))}
    y = jnp.zeros((2,), jnp.int32)
    total, met = fusion.multimodal_loss(lg, y)
    assert set(met) >= {"F", "G_a", "G_b", "G"}
    assert np.isfinite(float(total))
