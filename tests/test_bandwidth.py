"""KKT bandwidth allocation (P4.2', Eqs. 41-49): feasibility + optimality."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.wireless import bandwidth as bw
from repro.wireless.channel import uplink_rate
from repro.wireless.params import WirelessParams

P = WirelessParams()


def _random_instance(rng, U):
    h = 10 ** rng.uniform(-7, -4, U)          # plausible channel gains
    Q = rng.uniform(0.0, 2.0, U)
    gamma = rng.uniform(3e5, 1.2e6, U)
    tau_rem = rng.uniform(0.004, 0.0095, U)
    return Q, gamma, h, tau_rem


def test_b_min_meets_latency_exactly():
    rng = np.random.default_rng(0)
    Q, gamma, h, tau_rem = _random_instance(rng, 5)
    for i in range(5):
        b = bw.b_min(gamma[i], h[i], tau_rem[i], P)
        if b is None:
            continue
        r = uplink_rate(np.array([b]), np.array([h[i]]), P)[0]
        assert r == pytest.approx(gamma[i] / tau_rem[i], rel=1e-3)


def test_b_min_infeasible_when_ceiling_too_low():
    # terrible channel: even infinite bandwidth can't meet the deadline
    assert bw.b_min(1e7, 1e-12, 0.001, P) is None
    assert bw.b_min(1e6, 1e-6, -0.1, P) is None     # no compute budget left


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_property_allocation_is_feasible(U, seed):
    rng = np.random.default_rng(seed)
    Q, gamma, h, tau_rem = _random_instance(rng, U)
    B = bw.allocate(Q, gamma, h, tau_rem, P)
    if B is None:
        # must genuinely be infeasible: sum of minimum bandwidths > B_max
        bmins = [bw.b_min(gamma[i], h[i], tau_rem[i], P) for i in range(U)]
        assert any(b is None for b in bmins) or sum(bmins) > P.B_max
        return
    assert np.all(B > 0)
    assert B.sum() <= P.B_max * (1 + 1e-6)
    r = uplink_rate(B, h, P)
    tau_com = gamma / r
    assert np.all(tau_com <= tau_rem * (1 + 1e-3))     # In1 satisfied


def test_kkt_beats_equal_split():
    """The KKT point must not be worse than naive equal bandwidth on J3."""
    rng = np.random.default_rng(7)
    for _ in range(10):
        U = 3
        Q, gamma, h, tau_rem = _random_instance(rng, U)
        B = bw.allocate(Q, gamma, h, tau_rem, P)
        if B is None:
            continue

        def J3(Bv):
            r = uplink_rate(Bv, h, P)
            return float((Q * P.p_tx * gamma / r).sum())

        Beq = np.full(U, P.B_max / U)
        req = uplink_rate(Beq, h, P)
        if np.all(gamma / req <= tau_rem):             # equal split feasible
            assert J3(B) <= J3(Beq) * (1 + 1e-3)


def test_kkt_matches_grid_search_two_clients():
    """Equivalence with the paper's interval enumeration: brute-force the
    2-client simplex and compare objectives."""
    rng = np.random.default_rng(3)
    hits = 0
    for _ in range(20):
        Q, gamma, h, tau_rem = _random_instance(rng, 2)
        B = bw.allocate(Q, gamma, h, tau_rem, P)
        if B is None:
            continue
        hits += 1

        def J3(b1):
            Bv = np.array([b1, P.B_max - b1])
            r = uplink_rate(Bv, h, P)
            tau = gamma / r
            if np.any(tau > tau_rem):
                return np.inf
            return float((Q * P.p_tx * gamma / r).sum())

        grid = np.linspace(1e3, P.B_max - 1e3, 4001)
        best = min(J3(b) for b in grid)
        got = J3(B[0] if abs(B.sum() - P.B_max) < 2 else B[0])
        # allocate() may return sum < B_max only when pinned at minima
        r = uplink_rate(B, h, P)
        ours = float((Q * P.p_tx * gamma / r).sum())
        assert ours <= best * (1 + 5e-3)
    assert hits >= 3          # the regime must produce solvable instances
