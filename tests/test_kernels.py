"""Pallas kernel sweeps (interpret mode) vs. pure-jnp ref oracles —
shapes x dtypes per the deliverable-(c) requirement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fusion_loss.kernel import fusion_loss_pallas
from repro.kernels.fusion_loss.ref import fusion_loss_ref
from repro.kernels.fusion_loss.ops import fused_multimodal_loss
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd_scan.kernel import ssd_chunk_pallas
from repro.kernels.ssd_scan.ref import ssd_chunk_ref
from repro.kernels.ssd_scan.ops import ssd_forward
from repro.models.mamba2 import ssd_chunked
from repro.core import fusion as core_fusion

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,T,V,bt,bv", [
    (1, 128, 1024, 64, 256),
    (2, 256, 2048, 128, 512),
    (3, 64, 4096, 64, 2048),
    (4, 128, 512, 128, 512),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fusion_loss_sweep(M, T, V, bt, bv, dtype):
    logits = jnp.asarray(RNG.normal(size=(M, T, V)) * 3, dtype)
    labels = jnp.asarray(RNG.integers(0, V, T), jnp.int32)
    avail = jnp.asarray(
        np.maximum(RNG.integers(0, 2, (M, T)),
                   (np.arange(M)[:, None] == 0)), jnp.float32)
    f1, m1 = fusion_loss_pallas(logits, labels, avail, block_t=bt,
                                block_v=bv, interpret=True)
    f2, m2 = fusion_loss_ref(logits, labels, avail)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2),
                               rtol=tol, atol=tol)


def test_fusion_loss_ops_matches_core_fusion():
    """Kernel front-end agrees with core.fusion.multimodal_loss totals."""
    B, S, V = 2, 8, 512
    lg = {"text": jnp.asarray(RNG.normal(size=(B, S, V)), jnp.float32),
          "vision": jnp.asarray(RNG.normal(size=(B, 1, V)), jnp.float32)}
    y = jnp.asarray(RNG.integers(0, V, (B, S)), jnp.int32)
    total_k, met_k = fused_multimodal_loss(lg, y, block_t=16, block_v=512,
                                           interpret=True)
    total_c, met_c = core_fusion.multimodal_loss(lg, y)
    np.testing.assert_allclose(float(total_k), float(total_c), rtol=1e-5)
    np.testing.assert_allclose(float(met_k["F"]), float(met_c["F"]),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,K,S,hd,win,bq,bk", [
    (1, 4, 2, 128, 64, None, 64, 64),
    (2, 4, 4, 256, 32, None, 128, 64),
    (1, 8, 2, 256, 64, 64, 64, 64),
    (1, 2, 1, 512, 128, 128, 128, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, K, S, hd, win, bq, bk, dtype):
    q = jnp.asarray(RNG.normal(size=(B, H, S, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, K, S, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, K, S, hd)), dtype)
    o1 = flash_attention_pallas(q, k, v, causal=True, window=win,
                                block_q=bq, block_k=bk, interpret=True)
    o2 = attention_ref(q, k, v, causal=True, window=win)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32), rtol=tol, atol=tol)


def test_flash_attention_ops_layout():
    """[B,S,H,hd] wrapper layout equals models.layers.chunked_attention."""
    from repro.models.layers import chunked_attention
    B, S, H, K, hd = 2, 128, 4, 2, 32
    q = jnp.asarray(RNG.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, K, hd)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, K, hd)), jnp.float32)
    o1 = flash_attention(q, k, v, causal=True, interpret=True,
                         block_q=64, block_k=64)
    o2 = chunked_attention(q, k, v, window=None, chunk=64)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,nc,Q,nh,hp,N", [
    (1, 2, 64, 2, 32, 16),
    (2, 4, 32, 4, 16, 8),
    (1, 1, 128, 8, 64, 32),
])
def test_ssd_chunk_sweep(B, nc, Q, nh, hp, N):
    x = jnp.asarray(RNG.normal(size=(B, nc, Q, nh, hp)), jnp.float32)
    cum = jnp.cumsum(jnp.asarray(
        -np.abs(RNG.normal(size=(B, nc, Q, nh)) * 0.1), jnp.float32), axis=2)
    Bm = jnp.asarray(RNG.normal(size=(B, nc, Q, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, nc, Q, N)), jnp.float32)
    y1, s1 = ssd_chunk_pallas(x, cum, Bm, Cm, interpret=True)
    y2, s2 = ssd_chunk_ref(x, cum, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 96)])
def test_ssd_forward_matches_model_path(S, chunk):
    B, nh, hp, N = 2, 4, 16, 8
    x = jnp.asarray(RNG.normal(size=(B, S, nh, hp)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(B, S, nh))) * 0.1 + 0.01,
                     jnp.float32)
    A = jnp.asarray(-np.abs(RNG.normal(size=nh)) - 0.1, jnp.float32)
    Bm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    Cm = jnp.asarray(RNG.normal(size=(B, S, N)), jnp.float32)
    o1 = ssd_forward(x, dt, A, Bm, Cm, chunk, interpret=True)
    o2 = ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-4)
