"""Fig.-4-style V-frontier: whole fused experiments over a dense drift-penalty
grid, with real eval metrics per V — JCSBA against the traced baselines.

For every policy, every V in the grid runs a complete R-round MFL experiment
(schedule → masked cohort BGD → Eq. 12 aggregation → queue/tracker refresh)
under one ``jit(vmap(scan))`` via ``FusedRoundEngine.scan_v_grid`` — sharded
across the local devices' ``("scenario",)`` mesh when more than one is
available.  The per-V *final global models* are then evaluated on the held-out
test split on host, so each frontier point carries multimodal + per-modality
accuracy, not just energy/participation — this replaces the old 5-point
energy-only ``fig4`` scan in benchmarks/run.py.

Baselines ignore V (their traced cores read only ``B_max``), so their rows
are the flat reference lines of the paper's Fig. 4; JCSBA's rows trace the
actual energy/accuracy trade-off.

  PYTHONPATH=src python -m benchmarks.v_frontier --json-out BENCH_v_frontier.json
  PYTHONPATH=src python -m benchmarks.run --v-frontier          # same artifact
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence

import numpy as np

DENSE_V_GRID = [0.0001, 0.001, 0.01, 0.05, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0,
                50.0, 100.0]


def run_frontier(policies: Sequence[str] = ("jcsba", "random"),
                 V_grid: Optional[Sequence[float]] = None,
                 K: int = 10, rounds: int = 40, dataset: str = "iemocap",
                 n_samples: Optional[int] = None, seed: int = 0,
                 E_add: float = 2e-4, mesh="auto") -> dict:
    import jax
    from benchmarks.fused_round import _make_experiment, _n_samples
    from repro.fl.fused_round import draw_round_xs

    V_grid = list(DENSE_V_GRID if V_grid is None else V_grid)
    n = n_samples or max(_n_samples(K), 200)
    out = {"benchmark": "v_frontier", "dataset": dataset, "K": K,
           "rounds": rounds, "seed": seed, "E_add": E_add,
           "V_grid": [float(v) for v in V_grid],
           "devices": len(jax.devices()),
           "regime": "fused whole-experiment scan per (policy, V); E_add "
                     "shrunk so the C5 energy constraint binds; eval on the "
                     "20% held-out split of the synthetic cohort",
           "policies": {}}
    for pol in policies:
        exp = _make_experiment(dataset, K, n, seed=seed, fused=True,
                               E_add=E_add, scheduler=pol)
        eng = exp._get_fused_engine()
        xs = draw_round_xs(exp, rounds)
        carries, auxs = jax.block_until_ready(
            eng.scan_v_grid(V_grid, exp._carry, xs, mesh=mesh))
        ok = np.asarray(auxs.ok)                       # [n_V, R, K]
        energy = np.asarray(carries.spent).sum(-1)     # [n_V]
        rows: List[dict] = []
        for i, V in enumerate(V_grid):
            params_i = jax.tree.map(lambda x: x[i], carries.params)
            metrics = exp.adapter.evaluate(params_i, exp.test_ds)
            rows.append({
                "V": float(V),
                "multimodal": round(metrics["multimodal"], 4),
                **{m: round(metrics[m], 4) for m in exp.all_mods},
                "loss": round(metrics["loss"], 4),
                "energy_J": round(float(energy[i]), 5),
                "mean_participants": round(float(ok[i].sum(-1).mean()), 2),
            })
            print(f"{pol:12s} V={V:<8g} mm={rows[-1]['multimodal']:.4f} "
                  f"E={rows[-1]['energy_J']:.4f}J "
                  f"part={rows[-1]['mean_participants']}", flush=True)
        out["policies"][pol] = rows
    return out


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=6, 4 rounds, 4-point V grid")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--policies", default="jcsba,random")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    policies = tuple(args.policies.split(","))
    if args.tiny:
        out = run_frontier(policies, V_grid=[0.01, 0.1, 1.0, 10.0], K=6,
                           rounds=args.rounds or 4, n_samples=120)
    else:
        out = run_frontier(policies, rounds=args.rounds or 40)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
