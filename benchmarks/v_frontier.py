"""Fig.-4 / Table-3 V-frontier: whole fused experiments over a dense
drift-penalty grid, with device-resident accuracy *curves* per (policy, V) —
JCSBA against all four traced baselines (random / round_robin / selection /
dropout).

For every policy, every V in the grid runs a complete R-round MFL experiment
(schedule → masked cohort BGD → Eq. 12 aggregation → queue/tracker refresh →
held-out eval) under one ``jit(vmap(scan))`` via ``FusedRoundEngine.
scan_v_grid`` — sharded across the local devices' ``("scenario",)`` mesh when
more than one is available.  Test metrics are computed *inside* the scan at
the ``--eval-every`` cadence (``fl.eval`` behind ``RoundXs.eval_flag``, final
round always included), so each frontier point carries a multimodal +
per-modality accuracy curve with **zero host eval calls** — the old version
paid n_V ``adapter.evaluate`` round-trips per policy and reported only final
metrics.

Baselines ignore V (their traced cores read only ``B_max``), so their rows
are the flat reference lines of the paper's Fig. 4; JCSBA's rows trace the
actual energy/accuracy trade-off.

  PYTHONPATH=src python -m benchmarks.v_frontier --json-out BENCH_v_frontier.json
  PYTHONPATH=src python -m benchmarks.run --v-frontier          # same artifact
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence

import numpy as np

DENSE_V_GRID = [0.0001, 0.001, 0.01, 0.05, 0.1, 0.5, 1.0, 2.0, 5.0, 10.0,
                50.0, 100.0]
ALL_POLICIES = ("jcsba", "random", "round_robin", "selection", "dropout")


def run_frontier(policies: Sequence[str] = ALL_POLICIES,
                 V_grid: Optional[Sequence[float]] = None,
                 K: int = 10, rounds: int = 40, dataset: str = "iemocap",
                 n_samples: Optional[int] = None, seed: int = 0,
                 E_add: float = 2e-4, eval_every: int = 5,
                 mesh="auto") -> dict:
    import jax
    from benchmarks.fused_round import _make_experiment, _n_samples
    from repro.fl.fused_round import draw_round_xs

    V_grid = list(DENSE_V_GRID if V_grid is None else V_grid)
    n = n_samples or max(_n_samples(K), 200)
    out = {"benchmark": "v_frontier", "dataset": dataset, "K": K,
           "rounds": rounds, "seed": seed, "E_add": E_add,
           "eval_every": eval_every,
           "V_grid": [float(v) for v in V_grid],
           "devices": len(jax.devices()),
           "regime": "fused whole-experiment scan per (policy, V); E_add "
                     "shrunk so the C5 energy constraint binds; device-"
                     "resident eval on the 20% held-out split at the "
                     "eval_every cadence (final round always evaluated)",
           "policies": {}}
    for pol in policies:
        exp = _make_experiment(dataset, K, n, seed=seed, engine="fused",
                               E_add=E_add, scheduler=pol,
                               eval_every=eval_every)
        eng = exp._get_fused_engine()
        xs = draw_round_xs(exp, rounds, include_final=True)
        carries, auxs = jax.block_until_ready(
            eng.scan_v_grid(V_grid, exp._carry, xs, mesh=mesh))
        ok = np.asarray(auxs.ok)                       # [n_V, R, K]
        energy = np.asarray(carries.spent).sum(-1)     # [n_V]
        emask = np.asarray(auxs.eval_mask)             # [n_V, R]
        metrics = {k: np.asarray(v)                    # each [n_V, R]
                   for k, v in auxs.metrics.items()}
        rows: List[dict] = []
        for i, V in enumerate(V_grid):
            pts = np.flatnonzero(emask[i])
            curve = {"round": [int(t) for t in pts]}
            for k, v in metrics.items():
                curve[k] = [round(float(v[i, t]), 4) for t in pts]
            final = {k: curve[k][-1] for k in metrics}
            rows.append({
                "V": float(V),
                "multimodal": final["multimodal"],
                **{m: final[m] for m in exp.all_mods},
                "loss": final["loss"],
                "energy_J": round(float(energy[i]), 5),
                "mean_participants": round(float(ok[i].sum(-1).mean()), 2),
                "curve": curve,
            })
            print(f"{pol:12s} V={V:<8g} mm={final['multimodal']:.4f} "
                  f"E={rows[-1]['energy_J']:.4f}J "
                  f"part={rows[-1]['mean_participants']} "
                  f"curve_pts={len(pts)}", flush=True)
        out["policies"][pol] = rows
    return out


def check_curves(out: dict) -> None:
    """Assert the Table-3 artifact is genuinely curve-bearing: every
    (policy, V) row has a curve whose round axis is strictly increasing,
    whose metric tracks all share that length, and whose final point equals
    the row's headline metrics.  CI runs this on the smoke artifact."""
    assert out["policies"], "no policies in artifact"
    for pol, rows in out["policies"].items():
        assert len(rows) == len(out["V_grid"]), pol
        for r in rows:
            curve = r.get("curve")
            assert curve and curve["round"], (pol, r.get("V"))
            rnds = curve["round"]
            assert all(b > a for a, b in zip(rnds, rnds[1:])), (pol, rnds)
            assert rnds[-1] == out["rounds"] - 1, (pol, rnds)
            for k, vals in curve.items():
                assert len(vals) == len(rnds), (pol, k)
            assert r["multimodal"] == curve["multimodal"][-1]


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=6, 4 rounds, 4-point V grid")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--policies", default=",".join(ALL_POLICIES))
    ap.add_argument("--eval-every", type=int, default=None,
                    help="device-eval cadence inside the scan (rounds); "
                         "the final round is always evaluated")
    ap.add_argument("--check-curves", action="store_true",
                    help="validate the curve fields of the artifact "
                         "(strictly increasing rounds, consistent lengths)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    policies = tuple(args.policies.split(","))
    if args.tiny:
        out = run_frontier(policies, V_grid=[0.01, 0.1, 1.0, 10.0], K=6,
                           rounds=args.rounds or 4, n_samples=120,
                           eval_every=args.eval_every or 2)
    else:
        out = run_frontier(policies, rounds=args.rounds or 40,
                           eval_every=args.eval_every or 5)
    if args.check_curves:
        check_curves(out)
        print("curve check OK")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
