"""Benchmark harness — one benchmark per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (one per benchmark).

  PYTHONPATH=src python -m benchmarks.run            # quick mode
  PYTHONPATH=src python -m benchmarks.run --full     # full repro runs

Benchmarks:
  table3_*            — final multimodal/unimodal accuracy per algorithm
                        (paper Table 3; reads benchmarks/results/repro if the
                        full experiment ran, else runs a short version)
  v_frontier_*        — Fig.-4/Table-3 V-frontier: dense V grid, whole fused
                        experiments per (policy, V) — JCSBA + all four traced
                        baselines incl. dropout — sharded over the local
                        devices, with device-resident multimodal + unimodal
                        accuracy curves per point (``--v-frontier`` runs only
                        this and writes BENCH_v_frontier.json; see
                        benchmarks/v_frontier.py)
  solver_runtime      — JCSBA per-round solve time (paper §VI: 0.008 s)
  bound_descent       — Theorem-2 bound vs measured loss descent
  kernel_*            — Pallas kernel oracles (interpret) + XLA-path timing
  roofline_rows       — #(arch x shape) rows with all three terms present
  batched_rounds_*    — round engine throughput, sequential vs batched vmap
                        (``--tiny`` shrinks it to the CI smoke config: K=4,
                        2 rounds, both paths; ``--json-out`` dumps all rows
                        plus the raw benchmark payloads as JSON)
  jcsba_solver_*      — JCSBA per-round solve time, sequential numpy vs the
                        fused jitted population solver, plus the vmapped
                        scenario-grid sweep (see benchmarks/jcsba_solver.py)
  fused_round_*       — full MFL round wall-clock: split pipeline (solver jit
                        + host hop + client jit) vs the fused one-program
                        round, stepwise and under lax.scan, plus the
                        whole-experiment V-grid sweep
                        (see benchmarks/fused_round.py)
  fusion_kernel_*     — custom-VJP Pallas fusion loss on the cohort BGD hot
                        path: fused rounds XLA vs kernel-backed loss across
                        J and samples/client, raw loss value_and_grad, and
                        the Gram-form ζ/δ tracker refresh vs the
                        direct-difference path
                        (see benchmarks/fusion_kernel.py)
  backbone_rounds_*   — fused-round throughput + peak temp memory per model
                        family (lstm-cnn / transformer / ssd) with remat on
                        and off (see benchmarks/backbone_rounds.py)
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

ROWS = []
PAYLOADS = {}          # raw per-benchmark result dicts, for --json-out
TINY = False


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def _time(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------
def bench_table3(quick: bool):
    from benchmarks.experiments import aggregate_table3, run_one
    table = aggregate_table3()
    if not table:
        for ds in (["crema_d"] if quick else ["crema_d", "iemocap"]):
            for algo in ["random", "jcsba"]:
                run_one(ds, algo, 0, rounds=20 if quick else 100,
                        n_samples=400 if quick else 800)
        table = aggregate_table3()
    for key, vals in sorted(table.items()):
        mods = [k for k in vals if k not in ("multimodal", "energy_total")]
        derived = (f"mm={vals.get('multimodal', 0):.4f};"
                   + ";".join(f"{m}={vals[m]:.4f}" for m in sorted(mods))
                   + f";E={vals.get('energy_total', 0):.3f}J")
        emit(f"table3_{key.replace('/', '_')}", 0.0, derived)


def bench_v_frontier(quick: bool):
    """Fig.-4 / Table-3 V-frontier via the sharded fused V-grid scan: dense
    V grid, whole experiments per (policy, V) for JCSBA + all four traced
    baselines (dropout included), with device-resident accuracy *curves* at
    the eval_every cadence — zero host eval calls inside the scan."""
    from benchmarks.v_frontier import check_curves, run_frontier
    if TINY:
        out = run_frontier(("jcsba", "random", "dropout"),
                           V_grid=[0.01, 0.1, 1.0, 10.0],
                           K=6, rounds=4, n_samples=120, eval_every=2)
    elif quick:
        out = run_frontier(("jcsba", "random", "dropout"),
                           V_grid=[0.001, 0.01, 0.1, 1.0, 10.0, 100.0],
                           rounds=16, eval_every=4)
    else:
        out = run_frontier()                # all five policies, dense grid
    check_curves(out)
    PAYLOADS["v_frontier"] = out
    for pol, rows in out["policies"].items():
        for r in rows:
            mods = [k for k in r if k not in
                    ("V", "multimodal", "loss", "energy_J",
                     "mean_participants", "curve")]
            emit(f"v_frontier_{pol}_V={r['V']:g}", 0.0,
                 f"mm={r['multimodal']:.4f};"
                 + ";".join(f"{m}={r[m]:.4f}" for m in sorted(mods))
                 + f";E={r['energy_J']:.4f}J;part={r['mean_participants']};"
                 f"curve_pts={len(r['curve']['round'])}")


def bench_scenario_zoo(quick: bool):
    """Scenario zoo: one sharded scan_scenario_grid over a grid mixing
    split laws (iid / dirichlet-α / natural groups), per-modality ω_m
    vectors and corruption models, each row evaluated on its own held-out
    split inside the scan (see benchmarks/scenario_zoo.py)."""
    from benchmarks.scenario_zoo import (check_curves, default_zoo, run_zoo,
                                         tiny_zoo)
    if TINY:
        out = run_zoo(tiny_zoo(), rounds=4, eval_every=2)
    elif quick:
        out = run_zoo(default_zoo(K=8, n_per_client=4, n_test=64),
                      rounds=12, eval_every=4)
    else:
        out = run_zoo(default_zoo(K=10, n_per_client=8, n_test=128))
    check_curves(out)
    PAYLOADS["scenario_zoo"] = out
    for r in out["scenarios"]:
        emit(f"scenario_zoo_{r['name']}", 0.0,
             f"mm={r['multimodal']:.4f};E={r['energy_J']:.4f}J;"
             f"part={r['mean_participants']};"
             f"curve_pts={len(r['curve']['round'])}")


def bench_solver_runtime(quick: bool):
    from repro.core.aggregation import unified_weights
    from repro.core.convergence import BoundState
    from repro.wireless import cost as wcost
    from repro.wireless.channel import Channel
    from repro.wireless.params import MODALITY_PROFILES, WirelessParams
    from repro.wireless.schedulers import ScheduleContext, make_scheduler
    P = WirelessParams()
    rng = np.random.default_rng(0)
    mods = [("audio", "image"), ("audio",), ("image",)] * 3 + \
        [("audio", "image")]
    sizes = [80] * 10
    cc = wcost.client_costs(sizes, mods, MODALITY_PROFILES["crema_d"], P)
    ch = Channel(P, rng)
    w = unified_weights(sizes, mods, ["audio", "image"])
    bound = BoundState(10, ["audio", "image"], mods, w, sizes)
    sched = make_scheduler("jcsba", rng)
    h = ch.draw()

    def solve():
        ctx = ScheduleContext(h=h, Q=rng.uniform(0, 0.01, 10), cost=cc,
                              params=P, bound=bound, round_idx=0,
                              model_dist=np.zeros(10),
                              client_modalities=mods)
        sched.schedule(ctx)

    us = _time(solve, n=3 if quick else 10)
    emit("solver_runtime", us,
         f"per_round={us / 1e6:.4f}s;paper=0.008s;tau_max=0.01s")


def bench_bound(quick: bool):
    """Theorem 2: measured per-round descent statistics under JCSBA."""
    from repro.fl.runtime import MFLExperiment
    exp = MFLExperiment(dataset="crema_d", scheduler="jcsba", n_samples=400,
                        seed=0, eval_every=1)
    exp.run(30 if quick else 80)
    losses = [r.metrics["loss"] for r in exp.history if r.metrics]
    descents = np.diff(losses)
    frac_descent = float((descents <= 0).mean())
    emit("bound_descent", 0.0,
         f"frac_rounds_descending={frac_descent:.2f};"
         f"total_drop={losses[0] - np.mean(losses[-3:]):.4f}")


def bench_kernels(quick: bool):
    import jax
    import jax.numpy as jnp
    from repro.kernels.fusion_loss.ref import fusion_loss_ref
    from repro.models.layers import chunked_attention
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(0)

    M, T, V = 2, 512, 32768
    logits = jnp.asarray(rng.normal(size=(M, T, V)), jnp.bfloat16)
    labels = jnp.asarray(rng.integers(0, V, T), jnp.int32)
    avail = jnp.ones((M, T), jnp.float32)
    f = jax.jit(fusion_loss_ref)
    us = _time(lambda: jax.block_until_ready(f(logits, labels, avail)))
    emit("kernel_fusion_loss_xla_ref", us, f"M={M};T={T};V={V}")

    B, S, H, K, hd = 1, 1024, 8, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, K, hd)), jnp.bfloat16)
    f2 = jax.jit(lambda q, k, v: chunked_attention(q, k, v, window=None,
                                                   chunk=256))
    us = _time(lambda: jax.block_until_ready(f2(q, k, v)))
    emit("kernel_flash_attention_xla_ref", us, f"S={S};H={H}")

    Bz, S2, nh, hp, N = 1, 2048, 8, 64, 64
    x = jnp.asarray(rng.normal(size=(Bz, S2, nh, hp)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(Bz, S2, nh))) * 0.1 + 0.01,
                     jnp.float32)
    A = jnp.asarray(-np.abs(rng.normal(size=nh)) - 0.1, jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bz, S2, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bz, S2, N)), jnp.float32)
    f3 = jax.jit(lambda *a: ssd_chunked(*a, chunk=256))
    us = _time(lambda: jax.block_until_ready(f3(x, dt, A, Bm, Cm)))
    emit("kernel_ssd_scan_xla_ref", us, f"S={S2};nh={nh}")


def bench_roofline(quick: bool):
    from benchmarks.roofline import table
    rows = table("16x16")
    emit("roofline_rows_16x16", 0.0, f"n={len(rows)}")
    rows2 = table("2x16x16")
    if rows2:
        emit("roofline_rows_2x16x16", 0.0, f"n={len(rows2)}")
    by_dom = {}
    for r in rows:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    emit("roofline_dominant_hist", 0.0,
         ";".join(f"{k}={v}" for k, v in sorted(by_dom.items())))


def bench_jcsba_solver(quick: bool):
    from benchmarks.jcsba_solver import run_benchmark
    if TINY:
        out = run_benchmark([6], rounds=2, sweep_rounds=2,
                            tau_grid=[0.01, 0.02], bmax_grid=[10e6],
                            datasets=["iemocap"])
    elif quick:
        out = run_benchmark([10, 50], rounds=3, sweep_rounds=5,
                            tau_grid=[0.01, 0.02], bmax_grid=[5e6, 10e6],
                            datasets=["crema_d"])
    else:
        out = run_benchmark([10, 50], rounds=5, sweep_rounds=10,
                            tau_grid=[0.005, 0.01, 0.02, 0.05],
                            bmax_grid=[5e6, 10e6, 20e6],
                            datasets=["crema_d", "iemocap"])
    PAYLOADS["jcsba_solver"] = out
    for r in out["per_round"]:
        emit(f"jcsba_solver_K={r['K']}_{r['solver']}",
             r["ms_per_round"] * 1e3,
             f"speedup_vs_seq={r['speedup_vs_seq']}x")
    for r in out["sweep"]:
        emit(f"jcsba_solver_sweep_K={r['K']}",
             r["wall_s"] / r["total_solves"] * 1e6,
             f"solves_per_sec={r['solves_per_sec']};"
             f"n_scenarios={r['n_scenarios']};rounds={r['rounds']}")


def bench_fused_round(quick: bool):
    from benchmarks.fused_round import run_benchmark
    if TINY:
        out = run_benchmark([4], rounds=2, sweep_rounds=2,
                            V_grid=[0.1, 1.0, 10.0])
    elif quick:
        out = run_benchmark([10, 50], rounds=3, sweep_rounds=5,
                            V_grid=[0.01, 0.1, 1.0, 10.0])
    else:
        out = run_benchmark([10, 50], rounds=5, sweep_rounds=10,
                            V_grid=[0.001, 0.01, 0.1, 0.5, 1.0, 2.0, 5.0,
                                    10.0])
    PAYLOADS["fused_round"] = out
    for r in out["per_round"]:
        emit(f"fused_round_{r['dataset']}_K={r['K']}_{r['engine']}",
             r["ms_per_round"] * 1e3,
             f"speedup_vs_split={r['speedup_vs_split']}x")
    s = out["v_sweep"]
    emit(f"fused_round_vsweep_K={s['K']}",
         s["wall_s"] / s["total_fused_rounds"] * 1e6,
         f"rounds_per_sec={s['rounds_per_sec']};n_V={len(s['V_grid'])};"
         f"rounds={s['rounds']}")


def bench_fusion_kernel(quick: bool):
    from benchmarks.fusion_kernel import run_benchmark
    if TINY:
        out = run_benchmark([4], spc_grid=[2.0], rounds=2,
                            raw_shape=(2, 64, 512), raw_blocks=(32, 256),
                            tracker_J=4, tracker_leaves=((32, 16), (16,)))
    elif quick:
        out = run_benchmark([6], spc_grid=[2.0], rounds=2,
                            raw_shape=(2, 256, 4096),
                            raw_blocks=(128, 2048))
    else:
        out = run_benchmark([6, 10], spc_grid=[2.0, 8.0], rounds=3)
    PAYLOADS["fusion_kernel"] = out
    for r in out["per_round"]:
        emit(f"fusion_kernel_round_K={r['K']}_spc={r['samples_per_client']:g}",
             1e6 / r["pallas_rounds_per_sec"],
             f"xla_rps={r['xla_rounds_per_sec']};"
             f"pallas_rps={r['pallas_rounds_per_sec']};"
             f"ratio={r['pallas_vs_xla']}x")
    raw = out["raw_loss"]
    emit("fusion_kernel_raw_loss", raw["pallas_ms"] * 1e3,
         f"xla_ms={raw['xla_ms']};pallas_ms={raw['pallas_ms']};"
         f"backend={raw['backend']}")
    t = out["tracker"]
    emit("fusion_kernel_tracker", t["gram_ms"] * 1e3,
         f"diff_ms={t['diff_ms']};gram_ms={t['gram_ms']};"
         f"speedup={t['gram_vs_diff']}x;drift={t['max_drift']:.2e}")


def bench_batched_rounds(quick: bool):
    from benchmarks.batched_rounds import run_benchmark
    if TINY:
        out = run_benchmark([4], rounds=2, datasets=["iemocap"])
    elif quick:
        out = run_benchmark([10, 50], rounds=3, datasets=["iemocap"])
    else:
        out = run_benchmark([10, 50, 200], rounds=5)
    PAYLOADS["batched_rounds"] = out
    for r in out["results"]:
        emit(f"batched_rounds_{r['dataset']}_K={r['K']}",
             1e6 / r["batched_rounds_per_sec"],
             f"seq_rps={r['seq_rounds_per_sec']};"
             f"batched_rps={r['batched_rounds_per_sec']};"
             f"speedup={r['speedup']}x")


def bench_backbone_rounds(quick: bool):
    from benchmarks.backbone_rounds import run_benchmark
    if TINY:
        out = run_benchmark(["lstm-cnn", "transformer", "ssd"], [50],
                            J=10, reps=2, dataset="iemocap", n_per_client=2)
    elif quick:
        out = run_benchmark(["lstm-cnn", "transformer", "ssd"], [50],
                            J=10, reps=3, dataset="iemocap", n_per_client=2)
    else:
        out = run_benchmark(["lstm-cnn", "transformer", "ssd"], [50, 5000],
                            J=10, reps=5, dataset="iemocap", n_per_client=2)
    PAYLOADS["backbone_rounds"] = out
    for r in out["per_round"]:
        emit(f"backbone_rounds_{r['arch']}_K={r['K']}_remat={int(r['remat'])}",
             r["ms_per_round"] * 1e3,
             f"rounds_per_s={r['rounds_per_s']};temp_bytes={r['temp_bytes']}")


def bench_serving(quick: bool):
    from benchmarks.serving import run_benchmark
    out = run_benchmark(tiny=TINY or quick)
    PAYLOADS["serving"] = out
    for r in out["prefill"]:
        emit(f"serving_prefill_{r['arch']}_S={r['prompt_len']}",
             r["bulk_ms"] * 1e3,
             f"teacher_forced_ms={r['teacher_forced_ms']};"
             f"bulk_ms={r['bulk_ms']};speedup={r['speedup']}x")
    s = out["steady_state"]
    emit(f"serving_steady_{s['arch']}_B={s['batch']}",
         s["decode"]["mean_ms"] * 1e3,
         f"tok_per_s={s['tokens_per_s']};p99_ms={s['decode']['p99_ms']}")
    c = out["continuous"]
    emit(f"serving_continuous_{c['arch']}",
         c["post_swap_decode"]["p99_ms"] * 1e3,
         f"tok_per_s={c['tokens_per_s']};"
         f"swap_spike_p99_ms={c['swap_spike_p99_ms']};"
         f"swap_ms={c['swap_wall']['mean_ms']};"
         f"recompiles={c['recompiles_post_warmup']}")


# ---------------------------------------------------------------------------
def main() -> None:
    global TINY
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode (shrinks supporting benches)")
    ap.add_argument("--v-frontier", action="store_true",
                    help="run only the Fig.-4 V-frontier (sharded fused "
                         "V-grid scan with eval metrics) and write "
                         "BENCH_v_frontier.json")
    ap.add_argument("--json-out", default=None,
                    help="dump emitted rows + raw payloads as JSON")
    args, _ = ap.parse_known_args()
    TINY = args.tiny
    quick = not args.full
    benches = {
        "table3": bench_table3,
        "v_frontier": bench_v_frontier,
        "scenario_zoo": bench_scenario_zoo,
        "solver_runtime": bench_solver_runtime,
        "bound": bench_bound,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
        "batched_rounds": bench_batched_rounds,
        "jcsba_solver": bench_jcsba_solver,
        "fused_round": bench_fused_round,
        "fusion_kernel": bench_fusion_kernel,
        "backbone_rounds": bench_backbone_rounds,
        "serving": bench_serving,
    }
    if args.v_frontier:
        args.only = "v_frontier"
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        try:
            fn(quick)
        except Exception as e:  # keep the harness running
            emit(f"{name}_ERROR", 0.0, f"{type(e).__name__}:{e}")
    if args.v_frontier and "v_frontier" in PAYLOADS:
        with open("BENCH_v_frontier.json", "w") as f:
            json.dump(PAYLOADS["v_frontier"], f, indent=2)
        print("wrote BENCH_v_frontier.json", flush=True)
    if args.json_out:
        payload = {"rows": [{"name": n, "us_per_call": u, "derived": d}
                            for n, u, d in ROWS],
                   "payloads": PAYLOADS}
        with open(args.json_out, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json_out}", flush=True)


if __name__ == "__main__":
    main()
