"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Hardware constants (TPU v5e target):
    peak   = 197 TFLOP/s bf16 per chip
    HBM bw = 819 GB/s per chip
    ICI    = ~50 GB/s per link (per chip, one direction)

Terms (single-pod table; dry-run JSONs are the source):
    compute_s    = FLOPs_global / (chips * peak)
    memory_s     = HLO_bytes_global / (chips * HBM_bw)
    collective_s = collective_operand_bytes_global / (chips * ICI_bw)

Methodology notes (also in EXPERIMENTS.md):
  * XLA's cost_analysis on a scanned layer stack counts the while-loop body
    ONCE.  We therefore report BOTH the raw HLO numbers and corrected values
    where the dominant per-layer quantities are scaled by n_blocks:
        flops_corr = hlo_flops + (n_blocks-1)/n_blocks * share_in_loop ≈
    We use the conservative closed form: flops_corr = hlo_flops_body_scaled =
    (hlo_flops - f_out) * n_blocks + f_out is not separable from the text, so
    instead: compute term uses analytic MODEL_FLOPS (exact by construction)
    and the HLO/MODEL ratio is the remat/redundancy diagnostic on the
    *unscaled* module.
  * cost_analysis numbers are per-device (post-SPMD partitioning), so
    global = per_device * chips.
  * collective bytes already include the n_blocks multiplier for loop bodies
    (see launch/hlo_analysis.py).
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load_records(mesh: str = "16x16") -> List[dict]:
    recs = []
    if not os.path.isdir(DRYRUN_DIR):
        return recs
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if not f.endswith(f"__{mesh}.json"):
            continue
        with open(os.path.join(DRYRUN_DIR, f)) as fh:
            recs.append(json.load(fh))
    return recs


def roofline_row(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    model_fl = rec["model_flops"]
    hlo_fl_dev = rec.get("hlo_flops", 0.0)
    hlo_by_dev = rec.get("hlo_bytes", 0.0)
    coll = rec.get("collectives", {}).get("total_operand_bytes", 0)

    compute_s = model_fl / (chips * PEAK_FLOPS)
    memory_s = hlo_by_dev / HBM_BW              # per-device bytes already
    collective_s = coll / ICI_BW                # per-device program bytes
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    util = model_fl / (chips * hlo_fl_dev) if hlo_fl_dev > 0 else float("nan")
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": model_fl,
        "hlo_flops_per_dev": hlo_fl_dev,
        "model_over_hlo": round(model_fl / chips / hlo_fl_dev, 3)
        if hlo_fl_dev else None,
        "bytes_per_dev_temp": rec.get("temp_size_in_bytes"),
        "args_bytes_per_dev": rec.get("argument_size_in_bytes"),
        "optimizer": rec.get("optimizer"),
        "collective_by_kind": rec.get("collectives", {}).get("bytes_by_kind"),
    }


def table(mesh: str = "16x16") -> List[dict]:
    rows = []
    for rec in load_records(mesh):
        row = roofline_row(rec)
        if row:
            rows.append(row)
    return rows


def print_table(mesh: str = "16x16"):
    rows = table(mesh)
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>11s} {'memory_s':>11s} "
           f"{'collect_s':>11s} {'dominant':>10s} {'MODEL/HLO':>9s}")
    print(hdr)
    for r in rows:
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:11.5f} "
              f"{r['memory_s']:11.5f} {r['collective_s']:11.5f} "
              f"{r['dominant']:>10s} "
              f"{(r['model_over_hlo'] or float('nan')):9.3f}")
    return rows


if __name__ == "__main__":
    import sys
    print_table(sys.argv[1] if len(sys.argv) > 1 else "16x16")
