"""Full faithful-repro experiment: Table 3 / Fig. 5 / Fig. 6.

Runs the 5 algorithms x 2 datasets x seeds for `rounds` communication rounds
on the synthetic CREMA-D / IEMOCAP stand-ins and saves per-round curves to
benchmarks/results/repro/<dataset>__<algo>__s<seed>.json.

  PYTHONPATH=src python -m benchmarks.experiments --rounds 100 --seeds 3
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

RESULTS = os.path.join(os.path.dirname(__file__), "results", "repro")

ALGOS = ["random", "round_robin", "selection", "dropout", "jcsba"]
DATASETS = ["crema_d", "iemocap"]
# Fig. 4 trade-off: V=1 for CREMA-D, V=0.1 for IEMOCAP (§VI-A)
V_CHOICE = {"crema_d": 1.0, "iemocap": 0.1}
# The paper's regime has D_k ≈ 744 samples/client so e_cmp+e_com ≳ E_add and
# the long-term energy constraint C5 binds.  Our synthetic shards are ~64
# samples; E_add is scaled by the same factor so the Lyapunov queues bind
# identically (Table-2 default stays in WirelessParams).
E_ADD = 0.002


def run_one(dataset: str, algo: str, seed: int, rounds: int,
            n_samples: int, force: bool = False) -> dict:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"{dataset}__{algo}__s{seed}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("rounds", 0) >= rounds:
            return rec
    from repro.fl.runtime import MFLExperiment
    from repro.wireless.params import WirelessParams
    exp = MFLExperiment(dataset=dataset, scheduler=algo, seed=seed,
                        n_samples=n_samples, V=V_CHOICE[dataset],
                        eval_every=2, params=WirelessParams(E_add=E_ADD))
    exp.run(rounds)
    curves = {"round": [], "multimodal": [], "loss": [], "energy": []}
    mods = exp.all_mods
    for m in mods:
        curves[m] = []
    for r in exp.history:
        if not r.metrics:
            continue
        curves["round"].append(r.round)
        curves["multimodal"].append(r.metrics["multimodal"])
        curves["loss"].append(r.metrics["loss"])
        curves["energy"].append(r.energy_total)
        for m in mods:
            curves[m].append(r.metrics[m])
    rec = {"dataset": dataset, "algo": algo, "seed": seed, "rounds": rounds,
           "curves": curves, "final": exp.final_metrics(),
           "modalities": mods}
    with open(path, "w") as f:
        json.dump(rec, f)
    print(f"[exp] {dataset}/{algo}/s{seed}: "
          f"mm={rec['final'].get('multimodal', 0):.4f} "
          f"E={rec['final'].get('energy_total', 0):.3f}J", flush=True)
    return rec


def aggregate_table3(rounds_min: int = 1):
    """Mean final accuracies per (dataset, algo) over seeds — Table 3."""
    out = {}
    if not os.path.isdir(RESULTS):
        return out
    for f in os.listdir(RESULTS):
        if not f.endswith(".json") or "__V" in f:
            continue
        with open(os.path.join(RESULTS, f)) as fh:
            rec = json.load(fh)
        key = (rec["dataset"], rec["algo"])
        out.setdefault(key, []).append(rec)
    table = {}
    for (ds, algo), recs in out.items():
        finals = {}
        for k in ["multimodal"] + recs[0]["modalities"] + ["energy_total"]:
            vals = [r["final"].get(k) for r in recs if k in r["final"]]
            if vals:
                finals[k] = float(np.mean(vals))
        table[f"{ds}/{algo}"] = finals
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--n-samples", type=int, default=800)
    ap.add_argument("--datasets", nargs="*", default=DATASETS)
    ap.add_argument("--algos", nargs="*", default=ALGOS)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    for ds in args.datasets:
        for algo in args.algos:
            for seed in range(args.seeds):
                run_one(ds, algo, seed, args.rounds, args.n_samples,
                        args.force)
    print(json.dumps(aggregate_table3(), indent=1))


if __name__ == "__main__":
    main()
