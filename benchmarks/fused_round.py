"""Fused round engine vs the PR-2 split pipeline, plus whole-experiment
scenario sweeps.

Three measurements:

* ``per_round`` — wall-clock per full MFL round (JCSBA schedule + local
  updates + Eq. 12 aggregation + queue/tracker refresh) for three drivers on
  identical configs: the *split* pipeline (PR 2: jitted solver, host hop,
  jitted batched clients, host aggregation/trackers — ``engine="batched"``), the
  *fused* per-round program (``engine="fused"``, one jit per round), and the
  fused program under ``run_scanned`` (R rounds per dispatch).  The
  acceptance number is fused-vs-split at K=50.
* ``v_sweep`` — whole experiments vmapped over a V grid:
  ``jit(vmap(scan(round_step)))`` runs every drift-penalty scenario for R
  rounds with its own queue/warm-start/tracker/model dynamics entirely on
  device — the Fig.-4 frontier workload (n_V × R fused rounds, zero host
  hops).

  PYTHONPATH=src python -m benchmarks.fused_round               # K=10/50
  PYTHONPATH=src python -m benchmarks.fused_round --tiny        # CI smoke
  PYTHONPATH=src python -m benchmarks.fused_round --json-out BENCH_fused_round.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np


def _make_experiment(dataset: str, K: int, n_samples: int, seed: int = 0,
                     E_add: float = 0.01, scheduler: str = "jcsba", **kw):
    from repro.fl.runtime import MFLExperiment
    from repro.wireless.params import WirelessParams
    # keep the paper's per-client bandwidth density (Table 2: 10 MHz for
    # K=10) as K grows, so JCSBA schedules real participant sets at every K —
    # with the default absolute B_max, K=50 rounds degenerate to empty
    # schedules and the split pipeline never even runs its client stage
    params = WirelessParams(K=K, B_max=1e6 * K, E_add=E_add)
    kw.setdefault("eval_every", 10 ** 9)      # benches skip eval by default
    return MFLExperiment(dataset=dataset, scheduler=scheduler, K=K,
                         n_samples=n_samples, seed=seed, params=params, **kw)


def _n_samples(K: int, samples_per_client: float = 2.0) -> int:
    # 0.8 = train fraction; keep every client shard non-empty
    return max(int(samples_per_client * K / 0.8), int(K / 0.8) + K)


# ---------------------------------------------------------------------------
def bench_per_round(K: int, rounds: int, dataset: str = "iemocap"
                    ) -> List[dict]:
    n = _n_samples(K)

    def time_loop(exp, use_scan: bool) -> float:
        if use_scan:
            exp.run_scanned(rounds)               # warmup: compile the scan
            t0 = time.perf_counter()
            exp.run_scanned(rounds)
            return (time.perf_counter() - t0) / rounds
        exp.run_round()                           # warmup: compile the step
        t0 = time.perf_counter()
        exp.run(rounds)
        return (time.perf_counter() - t0) / rounds

    secs = {
        "split": time_loop(_make_experiment(dataset, K, n, engine="batched"),
                           use_scan=False),
        "fused": time_loop(_make_experiment(dataset, K, n, engine="fused"),
                           use_scan=False),
        "fused_scan": time_loop(_make_experiment(dataset, K, n,
                                                 engine="fused"),
                                use_scan=True),
    }
    rows = []
    for name, s in secs.items():
        rows.append({"K": K, "dataset": dataset, "engine": name,
                     "rounds": rounds, "ms_per_round": round(s * 1e3, 3),
                     "speedup_vs_split": round(secs["split"] / s, 2)})
        print(f"per_round K={K:4d} {name:10s} {s * 1e3:9.2f} ms/round  "
              f"speedup_vs_split={secs['split'] / s:6.2f}x", flush=True)
    return rows


# ---------------------------------------------------------------------------
def bench_v_sweep(K: int, rounds: int, V_grid, dataset: str = "iemocap",
                  seed: int = 0, scheduler: str = "jcsba") -> dict:
    """jit(vmap(scan)): every V scenario runs a whole experiment on device,
    sharded over the local devices' scenario mesh when more than one exists
    (``scan_v_grid``'s auto mesh).

    The sweep regime shrinks ``E_add`` so the long-term energy constraint C5
    actually binds (the tiny synthetic shards draw ~2e-3 J per scheduled
    round — under the Table-2 allowance the Lyapunov queues never charge and
    every V collapses to the same schedule; cf. the same rescaling in
    benchmarks/experiments.py)."""
    import jax
    from repro.fl.fused_round import draw_round_xs

    exp = _make_experiment(dataset, K, _n_samples(K), seed=seed,
                           engine="fused", E_add=2e-4,
                           scheduler=scheduler)
    eng = exp._get_fused_engine()
    carry = exp._carry
    xs = draw_round_xs(exp, rounds)

    carries, auxs = jax.block_until_ready(
        eng.scan_v_grid(V_grid, carry, xs))                 # compile
    t0 = time.perf_counter()
    carries, auxs = jax.block_until_ready(
        eng.scan_v_grid(V_grid, carry, xs))
    wall = time.perf_counter() - t0

    n_sched = np.asarray(auxs.a).sum(-1)                    # [n_V, R]
    energy = np.asarray(carries.spent).sum(-1)              # [n_V]
    total = len(V_grid) * rounds
    row = {"K": K, "dataset": dataset, "rounds": rounds,
           "scheduler": scheduler,
           "devices": len(jax.devices()),
           "V_grid": [float(v) for v in V_grid],
           "total_fused_rounds": total, "wall_s": round(wall, 3),
           "rounds_per_sec": round(total / wall, 2),
           "energy_by_V": [round(float(e), 5) for e in energy],
           "mean_scheduled_by_V": [round(float(x), 2)
                                   for x in n_sched.mean(-1)]}
    print(f"v_sweep K={K} |V|={len(V_grid)} x {rounds} rounds: "
          f"{total} fused rounds in {wall:.2f}s -> "
          f"{row['rounds_per_sec']} rounds/s", flush=True)
    return row


# ---------------------------------------------------------------------------
def run_benchmark(Ks: List[int], rounds: int, sweep_rounds: int,
                  V_grid, dataset: str = "iemocap") -> dict:
    per_round = []
    for K in Ks:
        per_round.extend(bench_per_round(K, rounds, dataset))
    sweep = bench_v_sweep(Ks[-1], sweep_rounds, V_grid, dataset)
    return {"benchmark": "fused_round",
            "regime": "cross-device shards (~2 samples/client), JCSBA "
                      "schedule, Table-2 wireless params with B_max scaled "
                      "to 1 MHz/client",
            "per_round": per_round, "v_sweep": sweep}


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=4, 2 rounds, 3-point V grid")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    if args.tiny:
        out = run_benchmark([4], rounds=args.rounds or 2, sweep_rounds=2,
                            V_grid=[0.1, 1.0, 10.0])
    else:
        out = run_benchmark([10, 50], rounds=args.rounds or 5,
                            sweep_rounds=10,
                            V_grid=[0.001, 0.01, 0.1, 0.5, 1.0, 2.0, 5.0,
                                    10.0])
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
