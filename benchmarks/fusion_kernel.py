"""Custom-VJP fusion-loss kernel on the cohort BGD hot path.

Three measurements:

* ``per_round`` — full fused-round throughput for ``engine="fused"`` (XLA
  loss, ``core.fusion``) vs ``engine="fused:pallas"`` (kernel-backed loss
  with the custom-VJP backward) on identical configs, across cohort size J
  (every client scheduled, so J = K) and samples-per-client (the kernel's
  token axis T).  Identical algorithmic work — tests/test_fusion_vjp.py
  asserts the two engines match to f32 tolerance.
* ``raw_loss`` — value_and_grad of the loss alone at a moderate [M, T, V]:
  the jitted XLA reference (materialises softmax in the backward) vs the
  kernel path (one blocked pass, probabilities never materialised).
* ``tracker`` — the ζ/δ refresh: the direct-difference path
  (``aggregate_gradients_stacked_traced`` + per-row ‖g_j − ḡ‖, two
  O(J·|θ|) passes over the gradient stack) vs the Gram form
  (``grad_gram`` + ``tracker_update_gram``: one contraction, O(J²) refresh).

On CPU the kernel runs in Pallas interpret mode — correctness-true but
slow, so CPU ``per_round``/``raw_loss`` numbers favour XLA; the kernel
timings are meaningful on the TPU deploy target.  Recorded honestly
either way.

  PYTHONPATH=src python -m benchmarks.fusion_kernel                # K=6/10
  PYTHONPATH=src python -m benchmarks.fusion_kernel --tiny         # CI smoke
  PYTHONPATH=src python -m benchmarks.fusion_kernel --json-out BENCH_fusion_kernel.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np


def _time(fn, n=3, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n


# ---------------------------------------------------------------------------
def _rounds_per_sec(dataset: str, K: int, rounds: int, n_samples: int,
                    engine: str) -> float:
    from repro.fl.runtime import MFLExperiment
    from repro.wireless.params import WirelessParams
    params = WirelessParams(K=K, tau_max=1e6)     # latency never binds
    exp = MFLExperiment(dataset=dataset, scheduler="random", K=K,
                        n_samples=n_samples, seed=0, eval_every=10 ** 9,
                        params=params, scheduler_kwargs={"n_sched": K},
                        engine=engine)
    exp.run_round()                               # warmup: compile + stack
    t0 = time.perf_counter()
    exp.run(rounds)
    return rounds / (time.perf_counter() - t0)


def bench_per_round(Ks: List[int], spc_grid: List[float], rounds: int,
                    dataset: str = "crema_d") -> List[dict]:
    rows = []
    for K in Ks:
        for spc in spc_grid:
            n = max(int(spc * K / 0.8), int(K / 0.8) + K)
            xla = _rounds_per_sec(dataset, K, rounds, n, "fused")
            ker = _rounds_per_sec(dataset, K, rounds, n, "fused:pallas")
            row = {"dataset": dataset, "K": K, "samples_per_client": spc,
                   "n_samples": n, "rounds": rounds,
                   "xla_rounds_per_sec": round(xla, 4),
                   "pallas_rounds_per_sec": round(ker, 4),
                   "pallas_vs_xla": round(ker / xla, 3)}
            rows.append(row)
            print(f"per_round K={K:3d} spc={spc:4g}  xla={xla:8.3f} r/s  "
                  f"pallas={ker:8.3f} r/s  ratio={ker / xla:5.2f}x",
                  flush=True)
    return rows


# ---------------------------------------------------------------------------
def bench_raw_loss(M: int, T: int, V: int, bt: int, bv: int) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.kernels.fusion_loss import ops as kops
    from repro.kernels.fusion_loss.ref import fusion_loss_ref
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(M, T, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, T), jnp.int32)
    avail = jnp.asarray(rng.integers(0, 2, (M, T)) | (np.arange(M) == 0
                                                      )[:, None],
                        jnp.float32)
    cf = jnp.full((T,), 1.0 / T, jnp.float32)
    cm = jnp.full((M, T), 1.0 / T, jnp.float32)

    def via(loss_fn):
        def scalar(lg):
            f, m = loss_fn(lg)
            return (f * cf).sum() + (m * cm).sum()
        g = jax.jit(jax.value_and_grad(scalar))
        return _time(lambda: jax.block_until_ready(g(logits)))

    s_xla = via(lambda lg: fusion_loss_ref(lg, labels, avail))
    s_ker = via(lambda lg: kops.fusion_loss(lg, labels, avail,
                                            block_t=bt, block_v=bv))
    row = {"M": M, "T": T, "V": V, "block_t": bt, "block_v": bv,
           "backend": jax.default_backend(),
           "xla_ms": round(s_xla * 1e3, 3),
           "pallas_ms": round(s_ker * 1e3, 3),
           "pallas_vs_xla": round(s_xla / s_ker, 3)}
    print(f"raw_loss [{M},{T},{V}]  xla={row['xla_ms']}ms  "
          f"pallas={row['pallas_ms']}ms", flush=True)
    return row


# ---------------------------------------------------------------------------
def bench_tracker(J: int, K: int, leaf_shapes) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.core import aggregation as agg
    from repro.core.convergence import (grad_gram, tracker_update_cohort,
                                        tracker_update_gram)
    rng = np.random.default_rng(1)
    grads = {f"l{i}": jnp.asarray(rng.normal(size=(J,) + tuple(s)) * 0.1,
                                  jnp.float32)
             for i, s in enumerate(leaf_shapes)}
    w = jnp.asarray(rng.dirichlet(np.ones(J)), jnp.float32)
    mask = jnp.ones(J, bool)
    idx = jnp.arange(J)
    has = jnp.ones(K, bool)
    z0 = jnp.float32(0.5)
    d0 = jnp.linspace(0.1, 0.9, K).astype(jnp.float32)
    n_params = int(sum(np.prod(s) for s in leaf_shapes))

    @jax.jit
    def old(g):
        ag = agg.aggregate_gradients_stacked_traced({"m": g}, {"m": w})["m"]
        return tracker_update_cohort(z0, d0, g, ag, mask, idx, has, 0.5)

    @jax.jit
    def new(g):
        return tracker_update_gram(z0, d0, grad_gram(g), w, mask, idx,
                                   has, 0.5)

    (za, da), (zb, db) = old(grads), new(grads)
    drift = float(max(abs(za - zb), jnp.abs(da - db).max()))
    s_old = _time(lambda: jax.block_until_ready(old(grads)), n=5)
    s_new = _time(lambda: jax.block_until_ready(new(grads)), n=5)
    row = {"J": J, "K": K, "n_params_per_client": n_params,
           "diff_ms": round(s_old * 1e3, 4),
           "gram_ms": round(s_new * 1e3, 4),
           "gram_vs_diff": round(s_old / s_new, 3),
           "max_drift": drift}
    print(f"tracker J={J} |theta|={n_params}  diff={row['diff_ms']}ms  "
          f"gram={row['gram_ms']}ms  speedup={row['gram_vs_diff']}x  "
          f"drift={drift:.2e}", flush=True)
    return row


# ---------------------------------------------------------------------------
def run_benchmark(Ks: List[int], spc_grid: List[float], rounds: int,
                  raw_shape=(2, 512, 8192), raw_blocks=(128, 2048),
                  tracker_J: int = 16,
                  tracker_leaves=((256, 128), (128,), (128, 64), (64, 8)),
                  dataset: str = "crema_d") -> dict:
    per_round = bench_per_round(Ks, spc_grid, rounds, dataset)
    raw = bench_raw_loss(*raw_shape, *raw_blocks)
    trk = bench_tracker(tracker_J, max(Ks + [tracker_J]), tracker_leaves)
    return {"benchmark": "fusion_kernel",
            "regime": "all K scheduled (J = K), tau_max non-binding; "
                      "kernel runs interpret on CPU, compiled on TPU",
            "per_round": per_round, "raw_loss": raw, "tracker": trk}


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=4, 2 rounds, small raw/tracker shapes")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    if args.tiny:
        out = run_benchmark([4], spc_grid=[2.0], rounds=args.rounds or 2,
                            raw_shape=(2, 64, 512), raw_blocks=(32, 256),
                            tracker_J=4,
                            tracker_leaves=((32, 16), (16,)))
    else:
        out = run_benchmark([6, 10], spc_grid=[2.0, 8.0],
                            rounds=args.rounds or 3)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
