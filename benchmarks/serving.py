"""Serving benchmarks: bulk prefill vs teacher forcing, steady-state decode,
and the round-boundary hot-swap spike under live MFL training.

Three measurements:

* ``prefill`` — one chunked bulk pass filling the KV cache
  (``steps.make_bulk_prefill``) vs the legacy teacher-forced per-token loop,
  identical cache contents (tests/test_decode_consistency.py).  The
  acceptance number is the bulk speedup at prompt_len>=64 on the reduced
  config (target >=2x).
* ``steady_state`` — ContinuousServer decode with no training running:
  tokens/sec and the per-step latency distribution (p50/p95/p99) — the
  no-swap baseline.
* ``continuous`` — ``run_continuous``: fused MFL rounds interleaved with
  decode batches, params hot-swapped at every round boundary through the
  flat donated buffers (``launch/parambuf``).  Reports the p99 of the
  first-decode-step-after-swap latencies against the steady-state p99 (the
  swap-induced spike), the swap wall itself, and the post-warmup recompile
  count — which must be ZERO (asserted; the whole point of the donated
  buffer design).

  PYTHONPATH=src python -m benchmarks.serving                 # full
  PYTHONPATH=src python -m benchmarks.serving --tiny          # CI smoke
  PYTHONPATH=src python -m benchmarks.serving --tiny --json-out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np


def _pcts(xs) -> dict:
    a = np.asarray(xs, np.float64) * 1e3
    return {"p50_ms": round(float(np.percentile(a, 50)), 4),
            "p95_ms": round(float(np.percentile(a, 95)), 4),
            "p99_ms": round(float(np.percentile(a, 99)), 4),
            "mean_ms": round(float(a.mean()), 4)}


# ---------------------------------------------------------------------------
def bench_prefill(arch: str, B: int, prompt_len: int, reps: int) -> dict:
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch import steps as S
    from repro.launch.serve import teacher_forced_prefill
    from repro.models import transformer as T

    cfg = get_config(arch).reduced()
    rng = np.random.default_rng(0)
    params = S.init_fn(cfg)(jax.random.key(0))
    prompts = jnp.asarray(rng.integers(0, min(cfg.vocab_size, 1000),
                                       (B, prompt_len)), jnp.int32)
    max_len = prompt_len + 8
    serve_step = jax.jit(lambda p, c, t, i: T.decode_step(p, c, t, i, cfg))
    bulk = jax.jit(S.make_bulk_prefill(cfg, attn_chunk=64))

    def fresh():
        return T.init_cache(cfg, B, max_len, cfg.param_dtype)

    def run_tf():
        nxt, _ = teacher_forced_prefill(serve_step, params, fresh(), prompts)
        jax.block_until_ready(nxt)

    def run_bulk():
        nxt, _ = bulk(params, prompts, fresh())
        jax.block_until_ready(nxt)

    out = {"arch": arch, "batch": B, "prompt_len": prompt_len}
    for name, fn in (("teacher_forced", run_tf), ("bulk", run_bulk)):
        fn()                                    # warmup / compile
        walls = []
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            walls.append(time.perf_counter() - t0)
        out[f"{name}_ms"] = round(min(walls) * 1e3, 3)
    out["speedup"] = round(out["teacher_forced_ms"] / out["bulk_ms"], 2)
    print(f"[prefill] {arch} B={B} S={prompt_len}: "
          f"teacher-forced {out['teacher_forced_ms']}ms vs bulk "
          f"{out['bulk_ms']}ms -> {out['speedup']}x", flush=True)
    return out


# ---------------------------------------------------------------------------
def _make_server_and_exp(arch: str, B: int, prompt_len: int, budget: int):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.fl.runtime import MFLExperiment
    from repro.launch import steps as S
    from repro.launch.continuous import ContinuousServer

    cfg = get_config(arch).reduced()
    exp = MFLExperiment(dataset="iemocap", scheduler="jcsba", K=6,
                        n_samples=120, seed=0, eval_every=10 ** 9,
                        engine="fused")
    feats = {m: jnp.asarray(x[:B])
             for m, x in sorted(exp.test_ds.features.items())}
    lm = S.init_fn(cfg)(jax.random.key(0))
    server = ContinuousServer(cfg, lm, exp.global_params, feats,
                              max_len=prompt_len + budget + 8)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, min(cfg.vocab_size, 1000), (B, prompt_len))
    return exp, server, prompts


def bench_steady_state(arch: str, B: int, prompt_len: int,
                       gen_len: int) -> dict:
    import jax.numpy as jnp
    _, server, prompts = _make_server_and_exp(arch, B, prompt_len, gen_len)
    server.start(jnp.asarray(prompts, jnp.int32))
    server.decode_batch(4)                      # warmup
    walls = server.decode_batch(gen_len)
    toks = B * gen_len
    out = {"arch": arch, "batch": B, "prompt_len": prompt_len,
           "gen_len": gen_len,
           "tokens_per_s": round(toks / sum(walls), 1),
           "decode": _pcts(walls)}
    print(f"[steady] {arch} B={B}: {out['tokens_per_s']} tok/s "
          f"p50={out['decode']['p50_ms']}ms p99={out['decode']['p99_ms']}ms",
          flush=True)
    return out


def bench_continuous(arch: str, B: int, prompt_len: int, rounds: int,
                     steps_per_round: int, baseline_p99_ms: float) -> dict:
    from repro.launch.continuous import run_continuous
    exp, server, prompts = _make_server_and_exp(
        arch, B, prompt_len, rounds * steps_per_round)
    rep = run_continuous(exp, server, prompts, rounds=rounds,
                         steps_per_round=steps_per_round)
    recompiles = sum(rep["recompiles"].values())
    assert recompiles == 0, (
        f"post-warmup recompiles under live training: {rep['recompiles']} — "
        f"the donated-buffer hot-swap contract is broken")
    post = _pcts(rep["post_swap_latencies_s"])
    steady = _pcts(rep["steady_latencies_s"])
    out = {"arch": arch, "batch": B, "rounds": rounds,
           "steps_per_round": steps_per_round,
           "tokens_per_s": round(rep["tokens_per_s"], 1),
           "steady_decode": steady,
           "post_swap_decode": post,
           "swap_wall": _pcts(rep["swap_walls_s"]),
           "round_wall_ms": round(
               float(np.mean(rep["round_walls_s"])) * 1e3, 2),
           "no_swap_baseline_p99_ms": baseline_p99_ms,
           "swap_spike_p99_ms": round(post["p99_ms"] - baseline_p99_ms, 4),
           "recompiles_post_warmup": recompiles}
    print(f"[continuous] {arch} {rounds}x{steps_per_round} rounds/steps: "
          f"{out['tokens_per_s']} tok/s, post-swap p99 {post['p99_ms']}ms vs "
          f"no-swap baseline {baseline_p99_ms}ms, swap "
          f"{out['swap_wall']['mean_ms']}ms, recompiles={recompiles}",
          flush=True)
    return out


# ---------------------------------------------------------------------------
def run_benchmark(tiny: bool) -> dict:
    arch = "qwen3-0.6b"
    if tiny:
        B, prompt_len, gen_len = 2, 64, 24
        rounds, spr, reps = 2, 8, 3
    else:
        B, prompt_len, gen_len = 4, 128, 128
        rounds, spr, reps = 4, 32, 5
    prefill = [bench_prefill(arch, B, prompt_len, reps)]
    if not tiny:
        prefill.append(bench_prefill(arch, B, 64, reps))
    steady = bench_steady_state(arch, B, prompt_len, gen_len)
    cont = bench_continuous(arch, B, prompt_len, rounds, spr,
                            steady["decode"]["p99_ms"])
    return {"benchmark": "serving",
            "regime": "reduced config, CPU container; serving params behind "
                      "flat donated buffers, fused iemocap MFL training "
                      "(K=6) interleaved with decode",
            "prefill": prefill, "steady_state": steady,
            "continuous": cont}


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: B=2, prompt 64, 2 rounds x 8 steps")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    out = run_benchmark(args.tiny)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
