"""Population-scale fused rounds: per-round latency and working-set memory
vs K ∈ {50, 1k, 10k, 100k} at a fixed cohort J.

The cohort-gather round (fl/fused_round.py) keeps the BGD/aggregation hot
path O(J): the policy emits a static-J cohort index vector, ``round_step``
gathers the cohort's rows from the device-resident ``ClientStore``, and
Eq. 12 / tracker refresh run on [J] stacks (segment-sum scatter back to the
dense [K] rows).  Only O(K) *vector* physics (channel draw, feasibility,
queues) and the O(K·N·d) resident store scale with the population — so
per-round latency and the compiled program's temp working set should stay
nearly flat from K=50 to K=100k while the store grows by 2000x.  This
benchmark commits exactly that evidence:

* ``ms_per_round`` — wall-clock per fused round (compiled ``eng.step``,
  carry chained across reps so every round is a real state update).
* ``temp_bytes`` — XLA's peak temp allocation for the round program
  (``compiled.memory_analysis().temp_size_in_bytes``): the working set,
  excluding the resident store/carry arguments, which are reported
  separately (``arg_bytes``, ``store_mb``).

Populations are built with the vectorized ``data.partition.
synthetic_population`` (the per-client Python staging of ``partition``/
``stack_clients`` is prohibitive at K=100k) and enter the engine through
``FusedRoundEngine.from_store`` — no ``MFLExperiment`` host mirrors.
Wireless cost vectors follow Eqs. 15-18 exactly, vectorized over the
ownership masks; ``B_max`` keeps the paper's per-client bandwidth density
(1 MHz/client, as in benchmarks/fused_round.py) so schedules stay real.

``--mesh-smoke`` instead runs a short ``scan_v_grid`` sweep on the 2-D
("scenario", "clients") mesh — with ``--virtual-devices 4`` this exercises
the client-sharded store + masked-psum cohort gather on any machine (the
flag must be set before jax initializes, so it is handled at main() entry).

  PYTHONPATH=src python -m benchmarks.population_scale                # full
  PYTHONPATH=src python -m benchmarks.population_scale --tiny \
      --json-out BENCH_population_scale.json                          # CI
  PYTHONPATH=src python -m benchmarks.population_scale --mesh-smoke \
      --virtual-devices 4 --K 5000 --rounds 2                         # CI 2-D
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import List, Optional

import numpy as np



def build_population(K: int, n_per_client: int, dataset: str, params,
                     omega: float = 0.2, seed: int = 0):
    """Synthetic ClientStore with Eqs. 15-18 cost vectors, vectorized."""
    # deferred: importing repro pulls in jax, which must not initialize
    # before main() applies --virtual-devices to XLA_FLAGS
    from repro.data.partition import synthetic_population
    from repro.data.scenarios import DATASET_SHAPES
    from repro.wireless.cost import population_costs
    from repro.wireless.params import MODALITY_PROFILES

    shapes, n_classes = DATASET_SHAPES[dataset]
    store = synthetic_population(K, n_per_client, shapes, n_classes, omega,
                                 seed=seed)
    cost = population_costs(store.has_modality, store.modalities,
                            store.sizes, MODALITY_PROFILES[dataset], params)
    return dataclasses.replace(store,
                               gamma_bits=cost.gamma_bits.astype(np.float32),
                               tau_cmp=cost.tau_cmp.astype(np.float32),
                               e_cmp=cost.e_cmp.astype(np.float32))


def _make_engine(K: int, J: int, dataset: str, policy_name: str,
                 n_per_client: int, seed: int):
    from repro.fl.client import PaperModelAdapter
    from repro.fl.fused_round import FusedRoundEngine
    from repro.wireless.params import WirelessParams
    from repro.wireless.policies import JCSBAPolicy, RandomPolicy

    params = WirelessParams(K=K, B_max=1e6 * K, E_add=2e-4)
    store = build_population(K, n_per_client, dataset, params, seed=seed)
    if policy_name == "jcsba":
        policy = JCSBAPolicy(K, max_cohort=J)
    else:
        policy = RandomPolicy(K, J)
    eng = FusedRoundEngine.from_store(store, params,
                                      policy, PaperModelAdapter(dataset),
                                      V=1.0, seed=seed)
    return eng, params, store


def _round_xs(rng, channel, K: int):
    import jax.numpy as jnp
    from repro.fl.fused_round import RoundXs
    return RoundXs(jnp.asarray(channel.draw(), jnp.float32),
                   jnp.uint32(rng.integers(2 ** 31)),
                   jnp.asarray(rng.integers(2 ** 31, size=K,
                                            dtype=np.uint32)),
                   jnp.asarray(False))


# ---------------------------------------------------------------------------
def bench_K(K: int, J: int, reps: int, dataset: str = "iemocap",
            policy: str = "random", n_per_client: int = 2,
            seed: int = 0) -> dict:
    import jax
    from repro.wireless.channel import Channel

    eng, params, store = _make_engine(K, J, dataset, policy, n_per_client,
                                      seed)
    carry = eng.fresh_carry()
    rng = np.random.default_rng(seed + 1)
    channel = Channel(params, rng)
    xs = _round_xs(rng, channel, K)

    carry, _ = jax.block_until_ready(eng.step(carry, xs))   # compile + warmup
    # pregenerate the rounds' randomness (as draw_round_xs / scan would) so
    # the timing is the device program, not numpy's 100k-element draws
    xs_list = [_round_xs(rng, channel, K) for _ in range(reps)]
    t0 = time.perf_counter()
    for xs in xs_list:
        carry, aux = eng.step(carry, xs)
    jax.block_until_ready((carry, aux))
    ms = (time.perf_counter() - t0) / reps * 1e3

    mem = eng._jit_step.lower(carry, xs, eng._store).compile(
        ).memory_analysis()
    store_mb = sum(np.asarray(x).nbytes
                   for x in jax.tree.leaves(eng._store)) / 2 ** 20
    row = {"K": K, "J": J, "policy": policy, "dataset": dataset,
           "n_per_client": n_per_client, "reps": reps,
           "ms_per_round": round(ms, 3),
           "scheduled": int(np.asarray(aux.ok).sum()),
           "store_mb": round(store_mb, 2),
           "temp_bytes": None if mem is None else int(mem.temp_size_in_bytes),
           "arg_bytes": None if mem is None
           else int(mem.argument_size_in_bytes),
           "output_bytes": None if mem is None
           else int(mem.output_size_in_bytes)}
    tmp = "n/a" if mem is None else f"{mem.temp_size_in_bytes / 2 ** 20:.1f}"
    print(f"K={K:7d} J={J:3d} {policy:6s} {ms:9.2f} ms/round  "
          f"temp={tmp} MiB  store={store_mb:.1f} MiB", flush=True)
    return row


def run_benchmark(Ks: List[int], J: int, reps: int, dataset: str,
                  policy: str, n_per_client: int) -> dict:
    rows = [bench_K(K, J, reps, dataset, policy, n_per_client) for K in Ks]
    out = {"benchmark": "population_scale", "dataset": dataset, "J": J,
           "policy": policy,
           "regime": "cohort-gather fused rounds via FusedRoundEngine."
                     "from_store on a vectorized synthetic population; "
                     "B_max scaled to 1 MHz/client; eval disabled; "
                     "temp_bytes is XLA's peak temp allocation for the "
                     "compiled round (working set — the resident store is "
                     "arg_bytes/store_mb)",
           "per_round": rows}
    lat = {r["K"]: r["ms_per_round"] for r in rows}
    if len(Ks) > 1:
        ratio = lat[Ks[-1]] / lat[Ks[0]]
        out["latency_ratio_max_vs_min_K"] = round(ratio, 2)
        print(f"K={Ks[-1]} vs K={Ks[0]} per-round latency: {ratio:.2f}x "
              f"(population {Ks[-1] / Ks[0]:.0f}x larger)", flush=True)
    return out


# ---------------------------------------------------------------------------
def mesh_smoke(K: int, J: int, rounds: int, dataset: str, policy: str,
               n_per_client: int, seed: int = 0) -> dict:
    """One short V sweep on the 2-D ("scenario", "clients") mesh: the
    client-sharded store + masked-psum cohort gather end to end."""
    import jax
    from repro.fl.fused_round import RoundXs
    from repro.launch.mesh import make_population_mesh
    from repro.wireless.channel import Channel
    import jax.numpy as jnp

    n_dev = jax.device_count()
    eng, params, store = _make_engine(K, J, dataset, policy, n_per_client,
                                      seed)
    carry = eng.fresh_carry()
    rng = np.random.default_rng(seed + 1)
    channel = Channel(params, rng)
    per = [_round_xs(rng, channel, K) for _ in range(rounds)]
    xs = RoundXs(*(jnp.stack(x) for x in zip(*per)))
    V = [0.1, 1.0]

    mesh = make_population_mesh() if n_dev > 1 else None
    t0 = time.perf_counter()
    carries, auxs = jax.block_until_ready(
        eng.scan_v_grid(V, carry, xs, mesh=mesh))
    wall = time.perf_counter() - t0
    row = {"benchmark": "population_scale/mesh_smoke", "K": K, "J": J,
           "rounds": rounds, "policy": policy, "devices": n_dev,
           "mesh": None if mesh is None
           else {ax: int(n) for ax, n in mesh.shape.items()},
           "n_V": len(V), "wall_s": round(wall, 3),
           "scheduled_per_round": round(
               float(np.asarray(auxs.ok).sum(-1).mean()), 2)}
    print(f"mesh_smoke K={K} J={J} devices={n_dev} mesh={row['mesh']}: "
          f"{len(V)}x{rounds} rounds in {wall:.2f}s, "
          f"{row['scheduled_per_round']} scheduled/round", flush=True)
    assert row["scheduled_per_round"] > 0, "smoke scheduled nobody"
    return row


# ---------------------------------------------------------------------------
def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K in {50, 500}, 2 reps")
    ap.add_argument("--Ks", default=None,
                    help="comma-separated population sizes "
                         "(default 50,1000,10000,100000)")
    ap.add_argument("--K", type=int, default=5000,
                    help="population size for --mesh-smoke")
    ap.add_argument("--J", type=int, default=10, help="cohort size")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=2,
                    help="rounds per scenario for --mesh-smoke")
    ap.add_argument("--dataset", default="iemocap")
    ap.add_argument("--policy", default="random",
                    choices=["random", "jcsba"],
                    help="random guarantees exactly J scheduled; jcsba "
                         "caps its cohort vector at J (max_cohort)")
    ap.add_argument("--n-per-client", type=int, default=2)
    ap.add_argument("--mesh-smoke", action="store_true",
                    help="run the 2-D mesh sweep smoke instead of the "
                         "latency/memory scaling table")
    ap.add_argument("--virtual-devices", type=int, default=None,
                    help="XLA_FLAGS host-device override (set before jax "
                         "initializes; lets the 2-D mesh run on one CPU)")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    if args.virtual_devices:
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.virtual_devices}").strip()

    if args.mesh_smoke:
        out = mesh_smoke(args.K, args.J, args.rounds, args.dataset,
                         args.policy, args.n_per_client)
    else:
        if args.Ks:
            Ks = [int(k) for k in args.Ks.split(",")]
        elif args.tiny:
            Ks = [50, 500]
        else:
            Ks = [50, 1000, 10000, 100000]
        out = run_benchmark(Ks, args.J, args.reps or (2 if args.tiny else 5),
                            args.dataset, args.policy, args.n_per_client)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
