"""Scenario zoo: one sharded ``scan_scenario_grid`` over a grid that mixes
split laws, per-modality ω_m vectors and corruption models — the paper's
modal-heterogeneity claims stress-tested beyond its Table 3.

Every row of the grid is a frozen ``data.scenarios.ScenarioSpec`` (split:
iid | dirichlet-α | natural-groups; per-modality missing ratios ω_m and
SNRs; feature-noise / erasure / test-time-missing corruption; the Lyapunov V
as just another field).  ``stack_scenarios`` vectorizes them into stacked
``ClientStore``s + per-scenario solver-data rows, and ONE
``jit(vmap(scan))`` — sharded over the local devices' ``("scenario",)``
mesh when more than one is available — runs every scenario's whole R-round
experiment with device-resident eval, so each row of the committed artifact
carries an accuracy *curve* on its own held-out split.

The default grid covers ω up to 0.6 at M=2 — the regime where the
pre-fix partitioner crashed outright ("client lost every modality") — so
the artifact doubles as regression evidence for the corrected substrate.

``--check-parity`` reruns the grid on a single device and asserts the
sharded sweep is bit-exact (the acceptance contract also locked by
tests/test_scenarios.py).

  PYTHONPATH=src python -m benchmarks.scenario_zoo \
      --json-out BENCH_scenario_zoo.json                         # full
  PYTHONPATH=src python -m benchmarks.scenario_zoo --tiny \
      --check-parity --json-out BENCH_scenario_zoo.json          # CI smoke
"""
from __future__ import annotations

import argparse
import json
from typing import List, Optional, Sequence

import numpy as np


def default_zoo(K: int, n_per_client: int, n_test: int,
                seed: int = 0) -> List:
    """12 rows on iemocap (M=2: audio+text): split laws x ω_m vectors x
    corruption x V."""
    from repro.data.scenarios import ScenarioSpec

    geom = dict(dataset="iemocap", K=K, n_per_client=n_per_client,
                n_test=n_test)
    rows = [
        dict(name="iid", split="iid", omega=0.0),
        dict(name="iid,om=0.3", split="iid", omega=0.3),
        dict(name="iid,om=0.6", split="iid", omega=0.6),     # pre-fix crash
        dict(name="iid,om=0.6/0.2", split="iid", omega=(0.6, 0.2)),
        dict(name="dir01,om=0.3", split="dirichlet", alpha=0.1, omega=0.3),
        dict(name="dir05,om=0.3", split="dirichlet", alpha=0.5, omega=0.3),
        dict(name="nat,om=0.3", split="natural", alpha=0.5, n_groups=4,
             omega=0.3),
        dict(name="nat-sig2,om=0.3", split="natural", alpha=0.5, n_groups=4,
             group_sigma=2.0, omega=0.3),
        dict(name="iid,om=0.3,noise1", split="iid", omega=0.3,
             noise_sigma=1.0),
        dict(name="iid,om=0.3,erase03", split="iid", omega=0.3,
             erasure_rate=0.3),
        dict(name="iid,om=0.3,no-text", split="iid", omega=0.3,
             test_missing="text"),
        dict(name="iid,om=0.3,V=10", split="iid", omega=0.3, V=10.0),
    ]
    return [ScenarioSpec(seed=seed + i, **geom, **r)
            for i, r in enumerate(rows)]


def tiny_zoo(seed: int = 0) -> List:
    """CI smoke: 2 split laws x 2 ω points x 2 corruption settings = 8."""
    from repro.data.scenarios import ScenarioSpec

    geom = dict(dataset="iemocap", K=6, n_per_client=4, n_test=32)
    specs = []
    i = 0
    for split in ("iid", "dirichlet"):
        for omega in (0.2, 0.6):
            for noise in (0.0, 0.5):
                specs.append(ScenarioSpec(
                    split=split, alpha=0.3, omega=omega, noise_sigma=noise,
                    seed=seed + i, **geom))
                i += 1
    return specs


def run_zoo(specs: Sequence, rounds: int = 30, J: Optional[int] = None,
            eval_every: int = 5, seed: int = 0, mesh="auto") -> dict:
    import jax
    from repro.data.scenarios import stack_scenarios
    from repro.fl.client import PaperModelAdapter
    from repro.fl.fused_round import FusedRoundEngine, draw_population_xs
    from repro.wireless.channel import Channel
    from repro.wireless.params import WirelessParams
    from repro.wireless.policies import JCSBAPolicy

    s0 = specs[0]
    K = s0.K
    params = WirelessParams(K=K, B_max=1e6 * K, E_add=2e-4)
    grid = stack_scenarios(specs, params)
    eng = FusedRoundEngine.from_store(
        grid.store_row(0), params, JCSBAPolicy(K, max_cohort=J or K),
        PaperModelAdapter(s0.dataset), seed=seed)
    carry = eng.fresh_carry()
    rng = np.random.default_rng(seed + 1)
    xs = draw_population_xs(Channel(params, rng), rng, K, rounds,
                            eval_every=eval_every, include_final=True)
    test_sets = (grid.test_features, grid.test_labels)

    carries, auxs = jax.block_until_ready(eng.scan_scenario_grid(
        grid.overrides, carry, xs, stores=grid.stores,
        test_sets=test_sets, mesh=mesh))
    if _CHECK_PARITY:
        single = jax.block_until_ready(eng.scan_scenario_grid(
            grid.overrides, carry, xs, stores=grid.stores,
            test_sets=test_sets, mesh=None))
        mismatched = [
            i for i, (a, b) in enumerate(zip(
                jax.tree.leaves((carries, auxs)), jax.tree.leaves(single)))
            if not np.array_equal(np.asarray(a), np.asarray(b),
                                  equal_nan=True)]
        assert not mismatched, \
            f"sharded != single-device on leaves {mismatched}"
        print("parity: sharded sweep bit-exact vs single device", flush=True)

    ok = np.asarray(auxs.ok)                           # [S, R, K]
    energy = np.asarray(carries.spent).sum(-1)         # [S]
    emask = np.asarray(auxs.eval_mask)                 # [S, R]
    metrics = {k: np.asarray(v) for k, v in auxs.metrics.items()}
    out = {"benchmark": "scenario_zoo", "dataset": s0.dataset, "K": K,
           "n_per_client": s0.n_per_client, "n_test": s0.n_test,
           "rounds": rounds, "eval_every": eval_every, "seed": seed,
           "devices": len(jax.devices()),
           "regime": "one sharded scan_scenario_grid over stacked "
                     "ScenarioSpecs (per-scenario ClientStore + solver-data "
                     "rows + held-out split); JCSBA schedule; device-"
                     "resident eval at the eval_every cadence, final round "
                     "always included",
           "scenarios": []}
    for i, spec in enumerate(grid.specs):
        pts = np.flatnonzero(emask[i])
        curve = {"round": [int(t) for t in pts]}
        for k, v in metrics.items():
            curve[k] = [round(float(v[i, t]), 4) for t in pts]
        final = {k: curve[k][-1] for k in metrics}
        row = {"name": spec.label(), "split": spec.split,
               "alpha": spec.alpha if spec.split != "iid" else None,
               "omega": list(spec.omega), "snr": list(spec.snr),
               "noise_sigma": spec.noise_sigma,
               "erasure_rate": spec.erasure_rate,
               "test_missing": spec.test_missing,
               "V": spec.V, "seed": spec.seed,
               "multimodal": final["multimodal"], "loss": final["loss"],
               **{m: final[m] for m in spec.modalities},
               "energy_J": round(float(energy[i]), 5),
               "mean_participants": round(float(ok[i].sum(-1).mean()), 2),
               "curve": curve}
        out["scenarios"].append(row)
        print(f"{row['name']:24s} mm={final['multimodal']:.4f} "
              f"E={row['energy_J']:.4f}J part={row['mean_participants']} "
              f"curve_pts={len(pts)}", flush=True)
    return out


def check_curves(out: dict) -> None:
    """The same curve-bearing contract as the V-frontier artifact: strictly
    increasing round axes, consistent track lengths, headline == last curve
    point, ending at the final round."""
    rows = out["scenarios"]
    assert rows, "no scenarios in artifact"
    for r in rows:
        curve = r.get("curve")
        assert curve and curve["round"], r["name"]
        rnds = curve["round"]
        assert all(b > a for a, b in zip(rnds, rnds[1:])), (r["name"], rnds)
        assert rnds[-1] == out["rounds"] - 1, (r["name"], rnds)
        for k, vals in curve.items():
            assert len(vals) == len(rnds), (r["name"], k)
        assert r["multimodal"] == curve["multimodal"][-1], r["name"]


_CHECK_PARITY = False


def main(argv: Optional[List[str]] = None) -> dict:
    global _CHECK_PARITY
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: the 2x2x2 grid, K=6, 4 rounds")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--K", type=int, default=10)
    ap.add_argument("--n-per-client", type=int, default=8)
    ap.add_argument("--n-test", type=int, default=128)
    ap.add_argument("--eval-every", type=int, default=None)
    ap.add_argument("--check-parity", action="store_true",
                    help="rerun on a single device and assert the sharded "
                         "sweep is bit-exact")
    ap.add_argument("--check-curves", action="store_true")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    _CHECK_PARITY = args.check_parity
    if args.tiny:
        specs = tiny_zoo()
        out = run_zoo(specs, rounds=args.rounds or 4,
                      eval_every=args.eval_every or 2)
    else:
        specs = default_zoo(args.K, args.n_per_client, args.n_test)
        out = run_zoo(specs, rounds=args.rounds or 30,
                      eval_every=args.eval_every or 5)
    if args.check_curves:
        check_curves(out)
        print("curve check OK")
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
