"""Backbone rounds: fused-round throughput and peak memory per model family
(lstm-cnn / transformer / ssd) at K ∈ {50, 5000}, remat on and off.

The model-adapter layer (fl/client.py) runs transformer- and SSD-backed
unimodal encoders through the same cohort-gather fused round as the paper's
LSTM/CNN submodels.  This benchmark commits the cost of that architecture
axis:

* ``rounds_per_s`` / ``ms_per_round`` — wall-clock fused-round throughput
  (compiled ``eng.step``, carry chained across reps);
* ``temp_bytes`` — XLA's peak temp allocation for the round program
  (``compiled.memory_analysis().temp_size_in_bytes``): the activation
  working set the remat engine token exists to shrink — remat rows
  checkpoint each client's loss (``ModelAdapter.cohort_step``), trading
  recompute for [J]-stacked activation memory.

Populations/engines mirror benchmarks/population_scale.py (vectorized
``synthetic_population`` → ``FusedRoundEngine.from_store``, RandomPolicy at
a fixed cohort J, 1 MHz/client bandwidth density, eval disabled).

  PYTHONPATH=src python -m benchmarks.backbone_rounds \
      --json-out BENCH_backbone_rounds.json                           # full
  PYTHONPATH=src python -m benchmarks.backbone_rounds --tiny \
      --json-out BENCH_backbone_rounds.json                           # CI
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np

from .population_scale import _round_xs, build_population


def _make_engine(K: int, J: int, dataset: str, arch: str, remat: bool,
                 n_per_client: int, seed: int):
    from repro.fl.client import make_adapter
    from repro.fl.fused_round import FusedRoundEngine
    from repro.wireless.params import WirelessParams
    from repro.wireless.policies import RandomPolicy

    params = WirelessParams(K=K, B_max=1e6 * K, E_add=2e-4)
    store = build_population(K, n_per_client, dataset, params, seed=seed)
    adapter = make_adapter(dataset, arch, remat=remat)
    eng = FusedRoundEngine.from_store(store, params, RandomPolicy(K, J),
                                      adapter, V=1.0, seed=seed)
    return eng, params


def bench_row(arch: str, K: int, remat: bool, J: int, reps: int,
              dataset: str = "iemocap", n_per_client: int = 2,
              seed: int = 0) -> dict:
    import jax
    from repro.wireless.channel import Channel

    eng, params = _make_engine(K, J, dataset, arch, remat, n_per_client,
                               seed)
    carry = eng.fresh_carry()
    rng = np.random.default_rng(seed + 1)
    channel = Channel(params, rng)
    xs = _round_xs(rng, channel, K)

    carry, _ = jax.block_until_ready(eng.step(carry, xs))  # compile + warmup
    xs_list = [_round_xs(rng, channel, K) for _ in range(reps)]
    t0 = time.perf_counter()
    for xs in xs_list:
        carry, aux = eng.step(carry, xs)
    jax.block_until_ready((carry, aux))
    ms = (time.perf_counter() - t0) / reps * 1e3

    mem = eng._jit_step.lower(carry, xs, eng._store).compile(
        ).memory_analysis()
    row = {"arch": arch, "K": K, "remat": remat, "J": J, "reps": reps,
           "dataset": dataset, "n_per_client": n_per_client,
           "ms_per_round": round(ms, 3),
           "rounds_per_s": round(1e3 / ms, 2),
           "scheduled": int(np.asarray(aux.ok).sum()),
           "temp_bytes": None if mem is None else int(mem.temp_size_in_bytes),
           "arg_bytes": None if mem is None
           else int(mem.argument_size_in_bytes)}
    tmp = "n/a" if mem is None else f"{mem.temp_size_in_bytes / 2 ** 20:.1f}"
    print(f"{arch:12s} K={K:6d} remat={int(remat)} {ms:9.2f} ms/round "
          f"({row['rounds_per_s']:7.2f} rounds/s)  temp={tmp} MiB",
          flush=True)
    return row


def run_benchmark(archs: List[str], Ks: List[int], J: int, reps: int,
                  dataset: str, n_per_client: int) -> dict:
    rows = []
    for arch in archs:
        for K in Ks:
            for remat in (False, True):
                rows.append(bench_row(arch, K, remat, J, reps, dataset,
                                      n_per_client))
    out = {"benchmark": "backbone_rounds", "dataset": dataset, "J": J,
           "regime": "cohort-gather fused rounds via FusedRoundEngine."
                     "from_store, RandomPolicy at fixed J, 1 MHz/client "
                     "bandwidth, eval disabled; one row per (arch, K, "
                     "remat): remat=true checkpoint-wraps each client's "
                     "loss in the cohort vmap (ModelAdapter.cohort_step); "
                     "temp_bytes is XLA's peak temp allocation for the "
                     "compiled round program",
           "per_round": rows}
    base = {(r["arch"], r["K"]): r for r in rows if not r["remat"]}
    for r in rows:
        b = base.get((r["arch"], r["K"]))
        if r["remat"] and b and r["temp_bytes"] and b["temp_bytes"]:
            print(f"{r['arch']:12s} K={r['K']:6d} remat temp ratio: "
                  f"{r['temp_bytes'] / b['temp_bytes']:.2f}x, "
                  f"slowdown {r['ms_per_round'] / b['ms_per_round']:.2f}x",
                  flush=True)
    return out


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=50 only, 2 reps")
    ap.add_argument("--archs", default="lstm-cnn,transformer,ssd")
    ap.add_argument("--Ks", default=None,
                    help="comma-separated population sizes (default 50,5000)")
    ap.add_argument("--J", type=int, default=10, help="cohort size")
    ap.add_argument("--reps", type=int, default=None)
    ap.add_argument("--dataset", default="iemocap")
    ap.add_argument("--n-per-client", type=int, default=2)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)

    archs = [a for a in args.archs.split(",") if a]
    if args.Ks:
        Ks = [int(k) for k in args.Ks.split(",")]
    elif args.tiny:
        Ks = [50]
    else:
        Ks = [50, 5000]
    out = run_benchmark(archs, Ks, args.J,
                        args.reps or (2 if args.tiny else 5),
                        args.dataset, args.n_per_client)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
