"""§Perf hillclimb driver: run override variants of the three chosen pairs
and print the roofline terms per iteration.

  PYTHONPATH=src python -m benchmarks.hillclimb
"""
from __future__ import annotations

import json
import os
import sys

PAIRS = [
    # (arch, shape, list of override-dicts in hillclimb order)
    ("qwen2-72b", "train_4k", [
        {"loss_chunk": 512},
        {"loss_chunk": 512, "remat": 1},
        {"loss_chunk": 512, "attn_chunk": 512},
    ]),
    ("mamba2-370m", "prefill_32k", [
        {"residual": "seq_model"},
        {"tp_off": 1},
        {"residual": "seq_model", "attn_chunk": 512},
    ]),
    ("llava-next-34b", "train_4k", [
        {"loss_chunk": 512},
        {"loss_chunk": 512, "remat": 1},
    ]),
]


def terms(rec):
    from benchmarks.roofline import roofline_row
    row = roofline_row(rec)
    if row is None:
        return rec.get("status"), rec.get("error", "")[:160]
    return (f"compute={row['compute_s']:.4f}s memory={row['memory_s']:.4f}s "
            f"collective={row['collective_s']:.4f}s dom={row['dominant']}")


def main():
    from repro.launch.dryrun import run_one
    for arch, shape, variants in PAIRS:
        base = run_one(arch, shape, False)
        print(f"== {arch} x {shape} BASELINE: {terms(base)}")
        for ov in variants:
            rec = run_one(arch, shape, False, overrides=ov)
            print(f"   {ov}: {terms(rec)}")


if __name__ == "__main__":
    main()
