"""Round-engine throughput: sequential Python loop vs batched vmap.

Runs the full MFL round (scheduling + local updates + Eq. 12 aggregation +
trackers) with every client scheduled each round — the local-update fan-out
dominates, which is exactly the hot path the batched engine replaces.  The
latency budget is set non-binding so no scheduled client fails transmission
(the two paths then do identical algorithmic work on identical cohorts).

Default is the *cross-device* regime (the ROADMAP's millions-of-users
direction): per-client shards of ~2 samples, so the sequential path is
dominated by its K-per-round JAX re-entries while the batched path pays one.
``--samples-per-client`` moves toward the compute-bound cross-silo regime,
where both paths converge on raw FLOPs and the speedup shrinks — recorded
honestly either way.

  PYTHONPATH=src python -m benchmarks.batched_rounds                 # K=10/50/200
  PYTHONPATH=src python -m benchmarks.batched_rounds --tiny          # K=4, CI smoke
  PYTHONPATH=src python -m benchmarks.batched_rounds --json-out BENCH_batched_rounds.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional


def _make_experiment(dataset: str, K: int, n_samples: int, engine: str,
                     seed: int = 0):
    from repro.fl.runtime import MFLExperiment
    from repro.wireless.params import WirelessParams
    params = WirelessParams(K=K, tau_max=1e6)     # latency never binds
    return MFLExperiment(dataset=dataset, scheduler="random", K=K,
                         n_samples=n_samples, seed=seed, eval_every=10 ** 9,
                         params=params, scheduler_kwargs={"n_sched": K},
                         engine=engine)


def _rounds_per_sec(dataset: str, K: int, rounds: int, n_samples: int,
                    engine: str) -> float:
    exp = _make_experiment(dataset, K, n_samples, engine)
    exp.run_round()                               # warmup: compile + stack
    t0 = time.perf_counter()
    exp.run(rounds)
    dt = time.perf_counter() - t0
    assert all(len(r.participants) == K for r in exp.history), \
        "benchmark invalid: a scheduled client failed transmission"
    return rounds / dt


def run_benchmark(Ks: List[int], rounds: int = 5,
                  samples_per_client: float = 2.0,
                  datasets: Optional[List[str]] = None) -> dict:
    datasets = datasets or ["iemocap", "crema_d"]
    results = []
    for dataset in datasets:
        for K in Ks:
            # 0.8 = train fraction; keep every client shard non-empty
            n = max(int(samples_per_client * K / 0.8), int(K / 0.8) + K)
            seq = _rounds_per_sec(dataset, K, rounds, n, engine="seq")
            bat = _rounds_per_sec(dataset, K, rounds, n, engine="batched")
            row = {"dataset": dataset, "K": K, "rounds": rounds,
                   "n_samples": n,
                   "seq_rounds_per_sec": round(seq, 4),
                   "batched_rounds_per_sec": round(bat, 4),
                   "speedup": round(bat / seq, 2)}
            results.append(row)
            print(f"{dataset:8s} K={K:4d} n={n:5d}  seq={seq:8.3f} r/s  "
                  f"batched={bat:8.3f} r/s  speedup={bat / seq:6.2f}x",
                  flush=True)
    return {"benchmark": "batched_rounds",
            "unit": "rounds_per_sec",
            "regime": f"cross-device, ~{samples_per_client} samples/client, "
                      "all K scheduled, tau_max non-binding",
            "results": results}


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=4, 2 rounds, both paths")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--samples-per-client", type=float, default=2.0)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    if args.tiny:
        out = run_benchmark([4], rounds=args.rounds or 2,
                            samples_per_client=args.samples_per_client,
                            datasets=["iemocap"])
    else:
        out = run_benchmark([10, 50, 200], rounds=args.rounds or 5,
                            samples_per_client=args.samples_per_client)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
