"""JCSBA solver throughput: sequential numpy vs fused jitted batch, plus the
many-scenario sweep the batched solver unlocks.

Two measurements:

* ``per_round`` — wall-clock per JCSBA solve through ``JCSBAScheduler`` for
  each backend (``seq`` = the original scalar immune+KKT path, ``np`` = the
  float64 batched mirror, ``jax`` = the fused jitted program), identical
  round contexts per backend.  The acceptance number is the jax-vs-seq
  speedup at K=50.
* ``sweep`` — a scenario grid (τ_max × B_max × modality profile) solved as
  ``jit(vmap(scan(...)))``: every scenario runs T rounds with Lyapunov queue
  dynamics and warm-started antibodies entirely on device.  This is the
  workload that is intractable on the sequential path (it would be
  n_scenarios × T sequential solves).  With more than one local device the
  scenario axis is sharded over a ``("scenario",)`` mesh via ``shard_map``
  (``launch.mesh.make_sweep_mesh`` / ``launch.sharding``), so the grid
  scales with the device count.

``--experiments`` extends the sweep from solver-only rounds to *whole
experiments* per scenario: the fused round engine (fl/fused_round.py) scans
schedule → local BGD updates → Eq. 12 aggregation → queue/tracker refresh for
every scenario of a V grid under one ``jit(vmap(scan))`` — see
``benchmarks.fused_round.bench_v_sweep``, which it reuses.

  PYTHONPATH=src python -m benchmarks.jcsba_solver                # full
  PYTHONPATH=src python -m benchmarks.jcsba_solver --tiny         # CI smoke
  PYTHONPATH=src python -m benchmarks.jcsba_solver --json-out BENCH_jcsba_solver.json
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np


def _round_inputs(K: int, dataset: str, seed: int, params=None):
    """Static per-scenario pieces: costs, channel, bound trackers."""
    from repro.core.aggregation import unified_weights
    from repro.core.convergence import BoundState
    from repro.wireless import cost as wcost
    from repro.wireless.channel import Channel
    from repro.wireless.params import MODALITY_PROFILES, WirelessParams

    params = params or WirelessParams(K=K)
    rng = np.random.default_rng(seed)
    prof = MODALITY_PROFILES[dataset]
    m1, m2 = sorted(prof.keys())
    mods = ([(m1, m2), (m1,), (m2,)] * (K // 3 + 1))[:K]
    sizes = [80] * K
    cc = wcost.client_costs(sizes, mods, prof, params)
    ch = Channel(params, rng)
    w = unified_weights(sizes, mods, [m1, m2])
    bound = BoundState(K, [m1, m2], mods, w, sizes)
    for m in bound.mods:
        bound.zeta[m] = float(rng.uniform(0.5, 2.0))
        bound.delta[m] = rng.uniform(0.1, 0.6, K)
    return params, cc, ch, bound, mods, rng


# ---------------------------------------------------------------------------
def bench_per_round(K: int, rounds: int, dataset: str = "crema_d",
                    solvers=("seq", "jax")) -> List[dict]:
    from repro.wireless.schedulers import ScheduleContext, make_scheduler

    out = {}
    for solver in solvers:
        params, cc, ch, bound, mods, rng = _round_inputs(K, dataset, seed=0)
        sched = make_scheduler("jcsba", np.random.default_rng(1),
                               solver=solver)
        ctxs = [ScheduleContext(h=ch.draw(), Q=rng.uniform(0, 0.01, K),
                                cost=cc, params=params, bound=bound,
                                round_idx=t, model_dist=np.zeros(K),
                                client_modalities=mods)
                for t in range(rounds + 1)]
        sched.schedule(ctxs[0])                     # warmup (jit compile)
        t0 = time.perf_counter()
        for ctx in ctxs[1:]:
            sched.schedule(ctx)
        out[solver] = (time.perf_counter() - t0) / rounds
    rows = []
    for solver in solvers:
        rows.append({"K": K, "dataset": dataset, "solver": solver,
                     "rounds": rounds,
                     "ms_per_round": round(out[solver] * 1e3, 3),
                     "speedup_vs_seq": round(out["seq"] / out[solver], 2)})
        print(f"per_round K={K:4d} {solver:4s} "
              f"{out[solver] * 1e3:9.2f} ms/solve  "
              f"speedup={out['seq'] / out[solver]:6.2f}x", flush=True)
    return rows


# ---------------------------------------------------------------------------
def bench_sweep(K: int, rounds: int, tau_grid, bmax_grid,
                datasets=("crema_d", "iemocap"), seed: int = 0) -> dict:
    """jit(vmap(scan)): the full scenario grid × T rounds in one program —
    sharded over the local devices' ``("scenario",)`` mesh when more than one
    is available (``launch.mesh.make_sweep_mesh``), single-device vmap
    otherwise."""
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_sweep_mesh
    from repro.launch.sharding import (pad_leading_axis, scenario_shard_map,
                                       slice_leading_axis)
    from repro.wireless.lyapunov import queue_update
    from repro.wireless.params import WirelessParams
    from repro.wireless.solver import SolverHyper, build_solver_data
    from repro.wireless.solver.common import B_LO
    from repro.wireless.solver.jaxsolver import _rate, solve_core, to_device

    hp = SolverHyper()
    scen, h_seqs = [], []
    for dataset in datasets:
        for tau in tau_grid:
            for bmax in bmax_grid:
                params = WirelessParams(K=K, tau_max=tau, B_max=bmax)
                params_, cc, ch, bound, _, rng = _round_inputs(
                    K, dataset, seed, params)
                data = build_solver_data(ch.draw(), rng.uniform(0, 0.01, K),
                                         cc, params, bound, V=1.0)
                data["E_add"] = params.E_add
                scen.append(to_device(data))
                h_seqs.append(np.stack([ch.draw() for _ in range(rounds)]))
    n_scen = len(scen)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *scen)
    h_all = jnp.asarray(np.stack(h_seqs), jnp.float32)     # [N, T, K]
    keys = jax.random.split(jax.random.PRNGKey(seed), n_scen)

    def one_scenario(data, h_seq, key):
        def round_body(carry, h_t):
            Q, warm, key = carry
            key, sub = jax.random.split(key)
            d = dict(data)
            d["Q"], d["h"] = Q, h_t
            seeds = jnp.stack([warm, jnp.zeros_like(warm)])
            a, J, B = solve_core(d, seeds, sub, hp)
            r = _rate(jnp.maximum(B, B_LO), h_t, d["p_tx"], d["N0"])
            ecom = d["p_tx"] * jnp.where(a, d["gamma"] / r, 0.0)
            Q = queue_update(Q, a.astype(Q.dtype) * (ecom + d["e_cmp"]),
                             d["E_add"])
            return (Q, a, key), (J, a.sum())
        carry = (data["Q"], jnp.zeros(h_seq.shape[1], bool), key)
        _, (Js, nsched) = jax.lax.scan(round_body, carry, h_seq)
        return Js, nsched

    vm = jax.vmap(one_scenario)
    mesh = make_sweep_mesh()
    if mesh is not None:
        d = mesh.devices.size
        stacked, h_all, keys = (pad_leading_axis(x, d)
                                for x in (stacked, h_all, keys))
        run = jax.jit(scenario_shard_map(vm, mesh, n_args=3,
                                         sharded_args=(0, 1, 2)))
    else:
        run = jax.jit(vm)
    Js, ns = jax.block_until_ready(run(stacked, h_all, keys))   # compile
    t0 = time.perf_counter()
    Js, ns = jax.block_until_ready(run(stacked, h_all, keys))
    wall = time.perf_counter() - t0
    Js, ns = slice_leading_axis((Js, ns), n_scen)
    total = n_scen * rounds
    row = {"K": K, "n_scenarios": n_scen, "rounds": rounds,
           "grid": f"{len(datasets)} profiles x {len(tau_grid)} tau_max x "
                   f"{len(bmax_grid)} B_max",
           "devices": 1 if mesh is None else int(mesh.devices.size),
           "total_solves": total, "wall_s": round(wall, 3),
           "solves_per_sec": round(total / wall, 2),
           "mean_scheduled": round(float(np.mean(np.asarray(ns))), 2),
           "objective_finite": bool(np.isfinite(np.asarray(Js)).all())}
    print(f"sweep K={K} {row['grid']}: {total} solves in {wall:.2f}s "
          f"-> {row['solves_per_sec']} solves/s", flush=True)
    return row


# ---------------------------------------------------------------------------
def run_benchmark(Ks: List[int], rounds: int, sweep_rounds: int,
                  tau_grid, bmax_grid, datasets,
                  experiment_sweep: bool = False) -> dict:
    per_round = []
    for K in Ks:
        per_round.extend(bench_per_round(K, rounds, dataset=datasets[0]))
    sweep = [bench_sweep(Ks[-1], sweep_rounds, tau_grid, bmax_grid,
                         datasets)]
    seq_ms = {r["K"]: r["ms_per_round"] for r in per_round
              if r["solver"] == "seq"}
    for row in sweep:
        if row["K"] in seq_ms:
            est_seq_s = seq_ms[row["K"]] * 1e-3 * row["total_solves"]
            row["est_seq_wall_s"] = round(est_seq_s, 1)
            row["sweep_speedup_vs_seq"] = round(est_seq_s / row["wall_s"], 1)
    out = {"benchmark": "jcsba_solver",
           "regime": "random Q/h round contexts, Table-2 wireless params",
           "per_round": per_round, "sweep": sweep}
    if experiment_sweep:
        # solver-only scenarios → whole experiments per scenario: the fused
        # round engine scans every V scenario's full MFL dynamics on device
        from benchmarks.fused_round import bench_v_sweep
        out["experiment_sweep"] = bench_v_sweep(
            Ks[-1], sweep_rounds, V_grid=[0.01, 0.1, 1.0, 10.0],
            dataset=datasets[0])
    return out


def main(argv: Optional[List[str]] = None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: K=6, 2 rounds, 2x2 scenario grid")
    ap.add_argument("--experiments", action="store_true",
                    help="also scan whole experiments (fused round engine) "
                         "per V scenario")
    ap.add_argument("--rounds", type=int, default=None)
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args(argv)
    if args.tiny:
        out = run_benchmark([6], rounds=args.rounds or 2, sweep_rounds=2,
                            tau_grid=[0.01, 0.02], bmax_grid=[10e6],
                            datasets=["iemocap"],
                            experiment_sweep=args.experiments)
    else:
        out = run_benchmark([10, 50], rounds=args.rounds or 5,
                            sweep_rounds=10,
                            tau_grid=[0.005, 0.01, 0.02, 0.05],
                            bmax_grid=[5e6, 10e6, 20e6],
                            datasets=["crema_d", "iemocap"],
                            experiment_sweep=args.experiments)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"wrote {args.json_out}")
    return out


if __name__ == "__main__":
    main()
