"""Continuous serving under live MFL training: round-boundary params
hot-swap into a zero-recompile decode loop.

The "serve what you train" story (ROADMAP) made concrete.  A
``ContinuousServer`` holds the whole serving tree —

* ``lm``:       the static decode backbone (e.g. reduced qwen3-0.6b),
* ``fusion``:   the MFL global fusion params the training rounds refresh,
* ``coupling``: a fixed seeded [C, V] matrix projecting fused class logits
                into vocab space —

behind ONE flat donated buffer per dtype (``launch/parambuf``).  Decode
steps unpack params from the buffers inside the jitted step (static slices
XLA folds into views), and the per-request multimodal context enters as a
constant logit bias added at the sampling layer — the same decision-head
convention the VLM serve path documents (``steps.make_serve_step``): fused
class logits from the request's modality features, projected through
``coupling``.  Per-step decode is the backbone only.

A hot-swap (``swap``) is one donated device copy — ``parambuf.make_swap``
writes the fresh round's params into the old allocation — plus a bias
recompute; token/cache shapes never change, so the decode jit cache stays
warm across swaps: zero recompiles, by construction and by assertion
(``run_continuous`` counts traces before/after, the repo's
``FusedRoundEngine.trace_count`` idiom).

``run_continuous`` interleaves fused ``round_step`` scans with decode-step
batches, swapping at every round boundary and timing each decode step, so
``benchmarks/serving.py`` can report the p99 swap-induced spike against a
no-swap baseline.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fusion
from ..models import paper_models, transformer as T
from ..models.config import ModelConfig
from . import parambuf
from . import steps as S


class ContinuousServer:
    """Decode-serving engine whose params live behind flat donated buffers.

    ``request_feats`` is the batch's multimodal context (modality ->
    [B, ...] features, e.g. a slice of the experiment's held-out split) —
    it determines the per-request fusion bias and the serving batch size.
    """

    def __init__(self, cfg: ModelConfig, lm_params, fusion_params,
                 request_feats: Dict[str, jax.Array], *, max_len: int,
                 bias_scale: float = 0.1, coupling_seed: int = 0,
                 n_groups: int = 1, attn_chunk: int = 64, mesh=None):
        if cfg.arch_type == "audio":
            raise NotImplementedError(
                "audio archs serve through launch.serve (encoder-side cross "
                "K/V); the continuous harness drives T.decode_step backbones")
        self.cfg = cfg
        self.max_len = max_len
        self.feats = {m: jnp.asarray(x) for m, x in request_feats.items()}
        self.batch = next(iter(self.feats.values())).shape[0]

        n_classes = jax.eval_shape(
            lambda p, f: fusion.fuse_logits(paper_models.modal_logits(p, f)),
            fusion_params, self.feats).shape[-1]
        coupling = (jax.random.normal(jax.random.key(coupling_seed),
                                      (n_classes, cfg.vocab_size),
                                      jnp.float32) * bias_scale)
        # host-side refs for rebuilding the serving tree at swap time (the
        # hot path reads only the packed buffers)
        self._lm = jax.tree.map(jnp.asarray, lm_params)
        self._coupling = coupling
        tree = {"lm": self._lm, "fusion": fusion_params,
                "coupling": coupling}
        self.spec = parambuf.spec_of(tree)
        self.bufs = parambuf.pack(tree, self.spec)
        if mesh is not None:
            from .sharding import serving_buffer_shardings
            self.bufs = jax.device_put(
                self.bufs, serving_buffer_shardings(self.bufs, mesh))
        self._swap_fn = parambuf.make_swap(self.spec)

        # trace counters: incremented each time a body is *traced* — the
        # zero-recompile contract is "many steps/swaps, one trace each"
        self.decode_traces = 0
        self.prefill_traces = 0
        self.bias_traces = 0
        spec = self.spec

        def _decode(bufs, cache, token, index, bias):
            self.decode_traces += 1
            params = parambuf.unpack(bufs, spec)
            logits, cache = T.decode_step(params["lm"], cache, token, index,
                                          cfg)
            logits = logits.astype(jnp.float32) + bias[:, None, :]
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

        self._decode = jax.jit(_decode, donate_argnums=(1,))
        bulk = S.make_bulk_prefill(cfg, n_groups=n_groups,
                                   attn_chunk=attn_chunk)

        def _prefill(bufs, tokens, cache):
            self.prefill_traces += 1
            params = parambuf.unpack(bufs, spec)
            return bulk(params["lm"], tokens, cache)

        self._prefill = jax.jit(_prefill, donate_argnums=(2,))

        def _bias(bufs, feats):
            self.bias_traces += 1
            params = parambuf.unpack(bufs, spec)
            modal = paper_models.modal_logits(params["fusion"], feats)
            return fusion.fuse_logits(modal) @ params["coupling"]

        self._bias_fn = jax.jit(_bias)
        self.bias = self._bias_fn(self.bufs, self.feats)
        self.cache = None
        self.token = None
        self.index = 0

    # ------------------------------------------------------------------
    def start(self, prompts: jax.Array) -> None:
        """Bulk-prefill the prompt batch [B, S] and arm the decode loop."""
        B, S = prompts.shape
        assert B == self.batch, (B, self.batch)
        cache = T.init_cache(self.cfg, B, self.max_len, self.cfg.param_dtype)
        self.token, self.cache = self._prefill(
            self.bufs, jnp.asarray(prompts, jnp.int32), cache)
        self.index = S
        jax.block_until_ready(self.token)

    def decode_step(self) -> float:
        """One greedy decode step for the whole batch; returns seconds."""
        t0 = time.perf_counter()
        self.token, self.cache = self._decode(
            self.bufs, self.cache, self.token, jnp.int32(self.index),
            self.bias)
        jax.block_until_ready(self.token)
        self.index += 1
        return time.perf_counter() - t0

    def decode_batch(self, n: int) -> list:
        return [self.decode_step() for _ in range(n)]

    def swap(self, new_fusion_params) -> float:
        """Hot-swap fresh global fusion params: one donated device copy into
        the old buffer allocation + a bias recompute.  Returns seconds."""
        t0 = time.perf_counter()
        self.bufs = self._swap_fn(
            self.bufs, {"lm": self._lm, "fusion": new_fusion_params,
                        "coupling": self._coupling})
        self.bias = self._bias_fn(self.bufs, self.feats)
        jax.block_until_ready(self.bias)
        return time.perf_counter() - t0

    # ------------------------------------------------------------------
    def state(self):
        """Snapshot (cache, token, index) — decode steps donate the cache,
        so the snapshot copies it."""
        return (jax.tree.map(jnp.copy, self.cache), jnp.copy(self.token),
                self.index)

    def load_state(self, st) -> None:
        cache, token, index = st
        self.cache = jax.tree.map(jnp.copy, cache)
        self.token = jnp.copy(token)
        self.index = index

    def compile_counts(self) -> Dict[str, int]:
        """Python trace counters + jit cache sizes for every hot-path
        function — the quantities the zero-recompile assertion compares."""
        out = {"decode_traces": self.decode_traces,
               "prefill_traces": self.prefill_traces,
               "bias_traces": self.bias_traces,
               "swap_traces": _cache_size(self._swap_fn)}
        for name, fn in (("decode", self._decode),
                         ("prefill", self._prefill),
                         ("bias", self._bias_fn)):
            n = _cache_size(fn)
            if n is not None:
                out[f"{name}_cache"] = n
        return {k: v for k, v in out.items() if v is not None}


def _cache_size(jitted) -> Optional[int]:
    return jitted._cache_size() if hasattr(jitted, "_cache_size") else None


# ---------------------------------------------------------------------------
# the interleaved driver
# ---------------------------------------------------------------------------
def run_continuous(exp, server: ContinuousServer, prompts, *, rounds: int,
                   steps_per_round: int, warmup_steps: int = 4) -> dict:
    """Interleave fused MFL training rounds with decode-step batches,
    hot-swapping the round's fresh global params at every boundary.

    Warmup compiles every jitted path (prefill, decode, a same-params swap,
    bias); after it the jit caches must be stable — ``recompiles`` in the
    returned report counts any post-warmup trace, and the tests /
    CI smoke assert it is all-zero.  Per-decode-step wall times are split
    into ``post_swap`` (the first step after a swap — where a swap-induced
    spike would land) and ``steady`` so the bench can compare p99s.
    """
    if not getattr(exp, "fused", False):
        raise ValueError("run_continuous requires an MFLExperiment with "
                         "engine='fused' (the scanned round_step path)")
    eng = exp._get_fused_engine()
    server.start(jnp.asarray(prompts, jnp.int32))
    for _ in range(max(warmup_steps, 1)):
        server.decode_step()
    server.swap(jax.tree.map(jnp.asarray, exp.global_params))
    server.decode_step()
    baseline = server.compile_counts()

    steady, post_swap, swap_walls, round_walls = [], [], [], []
    for _ in range(rounds):
        t0 = time.perf_counter()
        exp.run_scanned(1)
        round_walls.append(time.perf_counter() - t0)
        swap_walls.append(server.swap(eng.round_params(exp._carry)))
        for s in range(steps_per_round):
            (post_swap if s == 0 else steady).append(server.decode_step())
    post = server.compile_counts()
    recompiles = {k: post[k] - baseline.get(k, 0) for k in post}
    tokens = server.batch * (rounds * steps_per_round)
    decode_wall = sum(steady) + sum(post_swap)
    return {
        "rounds": rounds, "steps_per_round": steps_per_round,
        "batch": server.batch, "tokens_decoded": tokens,
        "tokens_per_s": tokens / decode_wall if decode_wall else 0.0,
        "steady_latencies_s": steady,
        "post_swap_latencies_s": post_swap,
        "swap_walls_s": swap_walls,
        "round_walls_s": round_walls,
        "compile_counts": post,
        "recompiles": recompiles,
    }


# ---------------------------------------------------------------------------
def main():
    ap = argparse.ArgumentParser(
        description="continuous serving demo: decode stream + fused MFL "
                    "rounds with round-boundary hot-swap")
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--dataset", default="iemocap")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--steps-per-round", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--K", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from ..configs import get_config
    from ..fl.runtime import MFLExperiment
    cfg = get_config(args.arch).reduced()
    exp = MFLExperiment(dataset=args.dataset, scheduler="jcsba", K=args.K,
                        n_samples=120, seed=args.seed, eval_every=10 ** 9,
                        engine="fused")
    feats = {m: jnp.asarray(x[:args.batch])
             for m, x in sorted(exp.test_ds.features.items())}
    lm = S.init_fn(cfg)(jax.random.key(args.seed))
    server = ContinuousServer(
        cfg, lm, exp.global_params, feats,
        max_len=args.prompt_len + args.rounds * args.steps_per_round + 8)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, min(cfg.vocab_size, 1000),
                           (args.batch, args.prompt_len))
    rep = run_continuous(exp, server, prompts, rounds=args.rounds,
                         steps_per_round=args.steps_per_round)
    lat = np.array(rep["steady_latencies_s"]) * 1e3
    print(f"[continuous] arch={cfg.name} {rep['tokens_decoded']} tokens "
          f"@ {rep['tokens_per_s']:.1f} tok/s | decode p50={np.percentile(lat, 50):.2f}ms "
          f"p99={np.percentile(lat, 99):.2f}ms | swap "
          f"{np.mean(rep['swap_walls_s']) * 1e3:.2f}ms | "
          f"recompiles={sum(rep['recompiles'].values())}")
    assert sum(rep["recompiles"].values()) == 0, rep["recompiles"]
    return rep


if __name__ == "__main__":
    main()
