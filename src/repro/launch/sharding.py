"""Parameter / batch / cache sharding rules (DESIGN.md §6).

Tensor-parallel ("model" axis): attention heads, d_ff, MoE experts, mamba
d_inner/heads, vocab of embed/lm_head.
FSDP ("data" axis, + "pod" on the multi-pod mesh): the other large axis of
every big matrix, so params/grads/optimizer state scale down with the full
data-parallel world (ZeRO-3 style; XLA inserts the all-gathers).

Rules are matched on the '/'-joined pytree path; specs apply to the TRAILING
dims of the leaf so stacked block params ([n_blocks, ...]) get a leading None
automatically.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FS = "__FSDP__"          # placeholder replaced by the mesh's fsdp axes

_RULES: Sequence[Tuple[str, tuple]] = (
    # MoE experts [E, D, F] / [E, F, D]: experts over model, D over fsdp
    (r"ffn/(wg|wu)$",        ("model", FS, None)),
    (r"ffn/wd$",             ("model", None, FS)),
    (r"router$",             (None, None)),
    # shared expert + dense MLP [D, F] / [F, D]
    (r"(shared|ffn|mlp)/(wg|wu)/w$", (FS, "model")),
    (r"(shared|ffn|mlp)/wd/w$",      ("model", FS)),
    # attention
    (r"(wq|wk|wv)/w$",       (FS, "model")),
    (r"(wq|wk|wv)/b$",       ("model",)),
    (r"wo/w$",               ("model", FS)),
    (r"wo/b$",               (None,)),
    # mamba2
    (r"(wz|wx|wdt)$",        (FS, "model")),
    (r"(wB|wC)$",            (FS, None)),
    (r"conv_x$",             (None, "model")),
    (r"conv_bx$",            ("model",)),
    (r"(conv_B|conv_C)$",    (None, None)),
    (r"mixer/norm$",         ("model",)),
    (r"out_proj$",           ("model", FS)),
    # decision-fusion heads (small)
    (r"(vision|audio_head)/(proj|w1)$", (None, None)),
    (r"(vision|audio_head)/w2$",        (None, "model")),
    # embeddings
    (r"lm_head$",            (FS, "model")),
    (r"embed$",              ("model", FS)),
)


def _resolve(spec: tuple, fsdp: Optional[tuple]) -> tuple:
    # a singleton fsdp axis collapses to its bare name: P("data") and
    # P(("data",)) shard identically but do not compare equal as specs
    if fsdp is not None and len(fsdp) == 1:
        fsdp = fsdp[0]
    return tuple((fsdp if s == FS else s) for s in spec)


def param_pspec(path: str, ndim: int, fsdp: Optional[tuple]) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path):
            spec = _resolve(spec, fsdp)
            spec = spec[:ndim]
            pad = ndim - len(spec)
            return P(*((None,) * pad + tuple(spec)))
    return P(*((None,) * ndim))        # replicate (norms, scalars, biases)


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(p.key) if hasattr(p, "key") else f"#{getattr(p, 'idx', p)}")
    return "/".join(parts)


def _axis_prod(mesh, ax) -> int:
    names = ax if isinstance(ax, tuple) else (ax,)
    return int(np.prod([mesh.shape[n] for n in names]))


def sanitize_pspec(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on any dim whose size is not divisible by the mesh axes
    (pjit requires exact divisibility of explicitly-sharded inputs; e.g.
    GQA kv=8 heads cannot shard over model=16, whisper's 51865 vocab cannot
    shard over 16).  Dropped dims are recorded replicated."""
    dims = []
    for d in range(len(shape)):
        ax = spec[d] if d < len(spec) else None
        if ax is None:
            dims.append(None)
            continue
        dims.append(ax if shape[d] % _axis_prod(mesh, ax) == 0 else None)
    return P(*dims)


def sanitize_tree(pspecs, tree, mesh: Mesh):
    return jax.tree.map(
        lambda s, l: sanitize_pspec(s, l.shape, mesh), pspecs, tree)


def tree_pspecs(tree, fsdp: Optional[tuple], mesh: Optional[Mesh] = None):
    """PartitionSpec pytree matching `tree` (works on ShapeDtypeStructs)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    specs = [param_pspec(_path_str(path), np.ndim(leaf) if not hasattr(leaf, "ndim")
             else leaf.ndim, fsdp) for path, leaf in flat]
    out = jax.tree_util.tree_unflatten(treedef, specs)
    if mesh is not None:
        out = sanitize_tree(out, tree, mesh)
    return out


def tree_shardings(tree, mesh: Mesh, fsdp: Optional[tuple]):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_pspecs(tree, fsdp))


def serving_buffer_shardings(bufs, mesh: Mesh):
    """Shardings for the flat serving param buffers (launch/parambuf).

    Decode reads the whole parameter set every step, and the flat layout
    erases the per-tensor axes the `_RULES` table keys on — so the buffers
    are REPLICATED across the mesh: every device holds a full copy and a
    round-boundary hot-swap is one donated copy per device, no collective
    on the decode critical path."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), bufs)


# ---------------------------------------------------------------------------
# scenario sweeps: shard an embarrassingly-parallel grid's leading axis
# ---------------------------------------------------------------------------
def scenario_shard_map(fn, mesh: Mesh, n_args: int,
                       sharded_args: Sequence[int] = (0,)):
    """Wrap an already-vmapped sweep ``fn`` in ``shard_map`` over the mesh's
    ``"scenario"`` axis: arguments listed in ``sharded_args`` are split along
    their leading (scenario) axis, the rest are replicated, and every output
    leaf must carry a leading scenario axis.  Scenarios are independent whole
    programs (no cross-scenario collectives), so this is pure SPMD fan-out —
    wall-clock divides by the device count.  Pad the grid first
    (``pad_leading_axis``) when it doesn't divide the mesh."""
    from jax.experimental.shard_map import shard_map

    sharded = set(sharded_args)
    in_specs = tuple(P("scenario") if i in sharded else P()
                     for i in range(n_args))
    # check_rep=False: the replication checker mis-types lax.scan carries
    # that mix replicated and sharded leaves (upstream jax limitation); the
    # sweeps are collective-free, so the check buys nothing here
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=P("scenario"), check_rep=False)


# ---------------------------------------------------------------------------
# population sweeps: logical axis rules for the 2-D ("scenario", "clients")
# mesh.  MaxText-style indirection — callers name the LOGICAL axes of each
# tensor ("which axis is the client axis?") and the rules table maps them to
# mesh axes, so the round program never hard-codes a mesh layout and a rule
# absent from the mesh degrades to replication.
# ---------------------------------------------------------------------------
SWEEP_AXIS_RULES: Sequence[Tuple[str, Optional[str]]] = (
    ("scenario", "scenario"),   # grid rows — independent whole experiments
    ("clients", "clients"),     # population axis of the client store / xs
    ("rounds", None),           # the lax.scan axis — never sharded
    ("batch", None),            # per-client samples — never sharded
)


def logical_pspec(axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None,
                  rules=SWEEP_AXIS_RULES) -> P:
    """PartitionSpec for a tensor whose dims carry the given logical axis
    names (None = unnamed/replicated dim).  Names missing from the rules
    table, mapped to None, or mapped to an axis the ``mesh`` doesn't carry
    all resolve to replication — the same program runs on a 1-D
    ``("scenario",)`` mesh with the client axis silently unsharded."""
    table = dict(rules)
    dims = []
    for ax in axes:
        mesh_ax = table.get(ax) if ax is not None else None
        if (mesh is not None and mesh_ax is not None
                and mesh_ax not in mesh.axis_names):
            mesh_ax = None
        dims.append(mesh_ax)
    return P(*dims)


def population_shard_map(fn, mesh: Mesh, in_specs, out_specs):
    """``shard_map`` over the 2-D ``("scenario", "clients")`` mesh with
    explicit per-argument (pytree) specs — unlike ``scenario_shard_map``'s
    uniform leading-axis split, population sweeps shard different arguments
    along different axes: the V grid over "scenario", the client store and
    per-client randomness over "clients", the carry replicated.
    check_rep=False for the same scan-carry reason as ``scenario_shard_map``;
    the only collectives are the cohort gather's psums / all_gathers over
    "clients", whose outputs are replicated by construction."""
    from jax.experimental.shard_map import shard_map

    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def pad_leading_axis(tree, multiple: int):
    """Pad every leaf's leading axis to a multiple of ``multiple`` by
    repeating the last scenario (duplicate work, dropped by
    ``slice_leading_axis`` — never garbage values, so padded rows still
    execute the real program)."""
    import jax.numpy as jnp

    def pad(x):
        n = (-x.shape[0]) % multiple
        if n == 0:
            return x
        reps = jnp.broadcast_to(x[-1:], (n,) + x.shape[1:])
        return jnp.concatenate([jnp.asarray(x), reps])

    return jax.tree.map(pad, tree)


def slice_leading_axis(tree, n: int):
    """Drop the rows ``pad_leading_axis`` added."""
    return jax.tree.map(lambda x: x[:n], tree)


# ---------------------------------------------------------------------------
# optimizer state: same layout as the matching parameter
# ---------------------------------------------------------------------------
def opt_state_pspecs(opt_state_shape, params_shape, fsdp: Optional[tuple]):
    """Optimizer-state specs built structurally from the parameter specs:
    adam m/v mirror the parameter layout; adafactor row stats drop the last
    param dim, col stats the second-last; scalars replicate."""
    pspecs = tree_pspecs(params_shape, fsdp)

    def factored(spec_and_shape):
        spec, leaf = spec_and_shape
        s = tuple(spec)
        if leaf.ndim >= 2:
            return {"r": P(*s[:-1]), "c": P(*(s[:-2] + (s[-1],)))}
        return {"v": P(*s)}

    out = {}
    for key, sub in opt_state_shape.items():
        if key == "step":
            out[key] = P()
        elif key in ("m", "v"):
            out[key] = pspecs
        elif key == "f":
            out[key] = jax.tree.map(
                lambda spec, leaf: factored((spec, leaf)), pspecs, params_shape)
        else:
            out[key] = jax.tree.map(lambda _: P(), sub)
    return out
