"""Post-SPMD HLO analysis: collective inventory + byte accounting.

``compiled.as_text()`` is the per-device program after the SPMD partitioner
inserted collectives.  We sum operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.

Loop-body accounting: the layer stack is a ``lax.scan`` → a ``while`` op whose
body is a separate HLO computation; a collective inside it executes
``n_blocks`` times but appears once in the text.  ``loop_multiplier`` is
applied to collectives found in computations whose name marks them as while
bodies.  (The only loops containing collectives in our models are the block
scans — the flash-attention q-chunk scan is shard-local by construction.)

Operand-byte convention per op kind (result bytes R, group size g):
  all-reduce          operand = R
  all-gather          operand = R / g          (each rank contributes a slice)
  reduce-scatter      operand = R * g
  all-to-all          operand = R
  collective-permute  operand = R
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?:\(|)([a-z0-9]+)\[([\d,]*)\][^=]*?\s("
    + "|".join(_KINDS) + r")(?:-start)?\(")
_TUPLE_OP_RE = re.compile(
    r"=\s*\(\s*([a-z0-9]+)\[([\d,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_RE = re.compile(r"^(%?[\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{|^ENTRY")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    computation: str
    result_bytes: int
    group_size: int
    operand_bytes: int
    multiplier: int


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str,
                      loop_multiplier: int = 1) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    comp = "ENTRY"
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.endswith("{") and ("->" in ls or ls.startswith("ENTRY")):
            m = re.match(r"%?([\w.\-]+)", ls.replace("ENTRY ", ""))
            comp = m.group(1) if m else ls[:40]
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        rb = _shape_bytes(dtype, dims)
        # async tuple results: take the payload element shape
        if "(" in line.split("=", 1)[1][:4]:
            tm = _TUPLE_OP_RE.search(line)
            if tm:
                rb = _shape_bytes(tm.group(1), tm.group(2))
        g = 1
        gm = _GROUPS_LIST_RE.search(line)
        if gm:
            g = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        else:
            gm = _GROUPS_IOTA_RE.search(line)
            if gm:
                g = int(gm.group(2))
        if kind == "all-gather":
            ob = rb // max(g, 1)
        elif kind == "reduce-scatter":
            ob = rb * g
        else:
            ob = rb
        is_loop_body = ("while" in comp) or ("body" in comp) or ("cond" in comp)
        mult = loop_multiplier if (is_loop_body and "cond" not in comp) else 1
        ops.append(CollectiveOp(kind, comp, rb, g, ob, mult))
    return ops


def summarize(ops: List[CollectiveOp]) -> Dict:
    total = 0
    by_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for op in ops:
        b = op.operand_bytes * op.multiplier
        total += b
        by_kind[op.kind] = by_kind.get(op.kind, 0) + b
        counts[op.kind] = counts.get(op.kind, 0) + op.multiplier
    return {"total_operand_bytes": int(total),
            "bytes_by_kind": {k: int(v) for k, v in by_kind.items()},
            "op_counts": counts,
            "n_sites": len(ops)}
