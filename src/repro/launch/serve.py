"""Batched serving driver: prefill a prompt batch, then greedy-decode.

On this CPU container use ``--reduced``; the production path is the same code
under the dry-run mesh/shardings.  For VLM archs the vision decision head's
logit bias is computed once at prefill and added at the sampling layer —
per-step decode is the backbone only (see steps.make_serve_step docstring).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import transformer as T, encdec
from . import steps as S


def serve(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = S.init_fn(cfg)(jax.random.key(args.seed))
    B = args.batch
    prompt_len = args.prompt_len
    max_len = prompt_len + args.gen_len
    prompts = jnp.asarray(rng.integers(
        0, min(cfg.vocab_size, 1000), (B, prompt_len)), jnp.int32)

    serve_step = jax.jit(S.make_serve_step(cfg), donate_argnums=(1,))

    if cfg.arch_type == "audio":
        src = jnp.asarray(rng.normal(size=(B, 64, cfg.d_model)),
                          cfg.param_dtype)
        enc = encdec.encode(params, src, cfg, attn_chunk=64)
        cache = encdec.init_dec_cache(cfg, B, max_len, src.shape[1],
                                      cfg.param_dtype)
        # precompute cross K/V from the encoder output
        from ..models import layers as L
        ck, cv = [], []
        for i in range(cfg.n_layers):
            bp = jax.tree.map(lambda x: x[i], params["dec_blocks"])
            k = L.dense(bp["cross_attn"]["wk"], enc).reshape(
                B, -1, cfg.n_kv_heads, cfg.hd)
            v = L.dense(bp["cross_attn"]["wv"], enc).reshape(
                B, -1, cfg.n_kv_heads, cfg.hd)
            ck.append(k)
            cv.append(v)
        cache["cross_k"] = jnp.stack(ck).astype(cache["cross_k"].dtype)
        cache["cross_v"] = jnp.stack(cv).astype(cache["cross_v"].dtype)
    else:
        cache = T.init_cache(cfg, B, max_len, cfg.param_dtype)

    # prefill by teacher-forcing the prompt through decode steps (fills the
    # cache exactly; a bulk prefill-with-cache-export is a future fast path)
    tok = prompts[:, :1]
    t0 = time.time()
    for i in range(prompt_len):
        nxt, cache = serve_step(params, cache, prompts[:, i:i + 1],
                                jnp.int32(i))
    generated = [nxt]
    for i in range(args.gen_len - 1):
        nxt, cache = serve_step(params, cache, generated[-1],
                                jnp.int32(prompt_len + i))
        generated.append(nxt)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    toks = B * (prompt_len + args.gen_len - 1)
    print(f"[serve] arch={cfg.name} batch={B} steps={toks} "
          f"{toks / dt:.1f} tok/s wall={dt:.2f}s")
    print("[serve] sample:", np.asarray(out[0])[:16].tolist())
    assert out.shape == (B, args.gen_len)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
