"""Batched serving driver: prefill a prompt batch, then greedy-decode.

On this CPU container use ``--reduced``; the production path is the same code
under the dry-run mesh/shardings.  For VLM archs the vision decision head's
logit bias is computed once at prefill and added at the sampling layer —
per-step decode is the backbone only (see steps.make_serve_step docstring).

Prefill runs as ONE bulk pass that fills the KV cache and exports it
(``steps.make_bulk_prefill``); ``--teacher-forced`` keeps the legacy
token-by-token path for A/B (``benchmarks/serving.py`` commits the ratio).
Audio archs precompute all layers' cross-K/V in one stacked einsum
(``encdec.cross_kv``).  For round-boundary params hot-swap under live MFL
training, see ``launch/continuous.py``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import transformer as T, encdec
from . import steps as S


def teacher_forced_prefill(serve_step, params, cache, prompts):
    """Legacy prefill: teacher-force the prompt one token at a time through
    decode steps.  Kept as the bulk path's A/B baseline — it fills the cache
    identically (tests/test_decode_consistency.py) at S times the
    dispatches."""
    prompt_len = prompts.shape[1]
    for i in range(prompt_len):
        nxt, cache = serve_step(params, cache, prompts[:, i:i + 1],
                                jnp.int32(i))
    return nxt, cache


def serve(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(args.seed)
    params = S.init_fn(cfg)(jax.random.key(args.seed))
    B = args.batch
    prompt_len = args.prompt_len
    max_len = prompt_len + args.gen_len
    prompts = jnp.asarray(rng.integers(
        0, min(cfg.vocab_size, 1000), (B, prompt_len)), jnp.int32)

    serve_step = jax.jit(S.make_serve_step(cfg), donate_argnums=(1,))

    enc = None
    if cfg.arch_type == "audio":
        src = jnp.asarray(rng.normal(size=(B, 64, cfg.d_model)),
                          cfg.param_dtype)
        enc = encdec.encode(params, src, cfg, attn_chunk=64)
        cache = encdec.init_dec_cache(cfg, B, max_len, src.shape[1],
                                      cfg.param_dtype)
        # cross K/V from the encoder output: one stacked einsum, all layers
        ck, cv = encdec.cross_kv(params, enc, cfg)
        cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
    else:
        cache = T.init_cache(cfg, B, max_len, cfg.param_dtype)

    t0 = time.time()
    if args.teacher_forced:
        nxt, cache = teacher_forced_prefill(serve_step, params, cache,
                                            prompts)
    else:
        bulk = jax.jit(S.make_bulk_prefill(cfg, attn_chunk=args.attn_chunk),
                       donate_argnums=(3,) if enc is not None else (2,))
        if enc is not None:
            nxt, cache = bulk(params, prompts, enc, cache)
        else:
            nxt, cache = bulk(params, prompts, cache)
    generated = [nxt]
    for i in range(args.gen_len - 1):
        nxt, cache = serve_step(params, cache, generated[-1],
                                jnp.int32(prompt_len + i))
        generated.append(nxt)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    toks = B * (prompt_len + args.gen_len - 1)
    mode = "teacher-forced" if args.teacher_forced else "bulk"
    print(f"[serve] arch={cfg.name} batch={B} prefill={mode} steps={toks} "
          f"{toks / dt:.1f} tok/s wall={dt:.2f}s")
    print("[serve] sample:", np.asarray(out[0])[:16].tolist())
    assert out.shape == (B, args.gen_len)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--attn-chunk", type=int, default=64)
    ap.add_argument("--teacher-forced", action="store_true",
                    help="legacy per-token prefill (A/B baseline)")
    ap.add_argument("--seed", type=int, default=0)
    serve(ap.parse_args())


if __name__ == "__main__":
    main()
