import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder devices and extract the roofline raw terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Each run writes benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis / cost_analysis numbers and the parsed collective inventory.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, get_config
from ..models import analysis as man
from . import hlo_analysis, sharding as shd, specs, steps
from .mesh import data_axes, fsdp_axes, make_production_mesh, n_data_shards

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _strip_axis(pspecs, axis: str):
    def strip(spec):
        return P(*[
            (None if ax == axis else
             (tuple(a for a in ax if a != axis) or None)
             if isinstance(ax, tuple) else ax)
            for ax in spec])
    return jax.tree.map(strip, pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                attn_chunk: int = 1024, overrides: dict = None,
                cfg_override=None):
    """Returns (lowered, compiled, info dict). Raises on failure.

    ``overrides`` — §Perf hillclimb levers:
      attn_chunk:int, loss_chunk:int, remat:bool,
      residual:"seq_model" (sequence-parallel residual stream),
      tp_off:bool (replicate params over the model axis).
    """
    cfg = cfg_override or get_config(arch)
    shape = specs.INPUT_SHAPES[shape_name]
    ok, why = specs.supports(cfg, shape)
    if not ok:
        return None, None, {"skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    fsdp = fsdp_axes(mesh)
    n_groups = n_data_shards(mesh)
    overrides = overrides or {}
    attn_chunk = overrides.get("attn_chunk", attn_chunk)
    bk = {}
    if overrides.get("loss_chunk"):
        bk["loss_chunk"] = int(overrides["loss_chunk"])
    if overrides.get("remat"):
        bk["remat"] = True
    if overrides.get("residual") == "seq_model":
        da = data_axes(mesh)
        bk["residual_spec"] = P(da if shape.global_batch > 1 else None,
                                "model", None)

    pshape = steps.params_shape(cfg)
    pspecs = shd.tree_pspecs(pshape, fsdp, mesh=mesh)
    if overrides.get("tp_off"):
        pspecs = _strip_axis(pspecs, "model")
    info = dict(man.model_flops(cfg, pshape, shape))
    info.update(arch=arch, shape=shape_name,
                mesh="2x16x16" if multi_pod else "16x16",
                n_devices=int(np.prod(list(mesh.shape.values()))))

    with mesh:
        if shape.kind == "train":
            optimizer, opt_name = steps.make_optimizer(cfg, info["n_params"])
            info["optimizer"] = opt_name
            oshape = jax.eval_shape(optimizer.init, pshape)
            ospecs = shd.sanitize_tree(
                shd.opt_state_pspecs(oshape, pshape, fsdp), oshape, mesh)
            if overrides.get("tp_off"):
                ospecs = _strip_axis(ospecs, "model")
            bshape = specs.batch_specs(cfg, shape)
            bspecs = specs.batch_pspecs(cfg, shape, mesh)
            fn = steps.make_train_step(cfg, optimizer, n_groups=n_groups,
                                       attn_chunk=attn_chunk, **bk)
            jfn = jax.jit(fn, in_shardings=(_ns(mesh, pspecs),
                                            _ns(mesh, ospecs),
                                            _ns(mesh, bspecs)),
                          donate_argnums=(0, 1))
            args = (pshape, oshape, bshape)
        elif shape.kind == "prefill":
            bshape = specs.batch_specs(cfg, shape)
            bspecs = specs.batch_pspecs(cfg, shape, mesh)
            fn = steps.make_prefill_step(cfg, n_groups=n_groups,
                                         attn_chunk=attn_chunk, **bk)
            jfn = jax.jit(fn, in_shardings=(_ns(mesh, pspecs),
                                            _ns(mesh, bspecs)))
            args = (pshape, bshape)
        else:  # decode
            cshape = specs.cache_specs(cfg, shape)
            cspecs = specs.cache_pspecs(cshape, cfg, shape, mesh)
            bshape = specs.batch_specs(cfg, shape)
            bspecs = specs.batch_pspecs(cfg, shape, mesh)
            fn = steps.make_serve_step(cfg)
            jfn = jax.jit(fn, in_shardings=(_ns(mesh, pspecs),
                                            _ns(mesh, cspecs),
                                            _ns(mesh, bspecs["token"]),
                                            _ns(mesh, bspecs["index"])),
                          donate_argnums=(1,))
            args = (pshape, cshape, bshape["token"], bshape["index"])

        t0 = time.time()
        lowered = jfn.lower(*args)
        info["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        info["compile_s"] = round(time.time() - t0, 2)
    return lowered, compiled, info


def analyse(lowered, compiled, info, cfg) -> dict:
    out = dict(info)
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = int(v)
    except Exception as e:  # CPU backend may not implement everything
        out["memory_analysis_error"] = str(e)
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        out["hlo_flops"] = float(ca.get("flops", -1.0))
        out["hlo_bytes"] = float(ca.get("bytes accessed", -1.0))
        out["hlo_transcendentals"] = float(ca.get("transcendentals", -1.0))
    except Exception as e:
        out["cost_analysis_error"] = str(e)
    try:
        txt = compiled.as_text()
        mult = cfg.n_blocks if cfg.arch_type != "audio" else cfg.n_layers
        ops = hlo_analysis.parse_collectives(txt, loop_multiplier=mult)
        out["collectives"] = hlo_analysis.summarize(ops)
        out["hlo_text_bytes"] = len(txt)
    except Exception as e:
        out["collectives_error"] = str(e)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, force: bool = False,
            overrides: dict = None) -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_tag}"
    if overrides:
        tag += "__" + "_".join(f"{k}{v}" for k, v in sorted(overrides.items()))
    path = os.path.join(RESULTS_DIR, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    try:
        lowered, compiled, info = lower_combo(arch, shape_name,
                                              multi_pod=multi_pod,
                                              overrides=overrides)
        if info.get("skipped"):
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "status": "skipped", "reason": info["reason"]}
        else:
            rec = analyse(lowered, compiled, info, cfg)
            rec["status"] = "ok"
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    print(f"[dryrun] {tag}: {status} "
          f"(compile={rec.get('compile_s', '-')}s)", flush=True)
    return rec


def calibrate(arch: str, shape_name: str, multi_pod: bool = False) -> dict:
    """Depth calibration: re-lower the SAME dims at 1 and 2 super-blocks.

    XLA's cost_analysis counts a while-loop body once; per-step cost is
    affine in depth, cost(n) = a + b*n, so two shallow compiles identify
    (a, b) and corrected(N) = c1 + (N-1)*(c2-c1).  The corrected values are
    patched into the combo's dry-run JSON (hlo_*_corrected)."""
    import dataclasses
    cfg = get_config(arch)
    bp = len(cfg.block_pattern())
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_tag}.json")
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok" or "calib" in rec:
        return rec
    vals = {}
    for n in (1, 2):
        kw = dict(n_layers=bp * n)
        if cfg.encoder_layers:
            kw["encoder_layers"] = n
        shallow = dataclasses.replace(cfg, **kw)
        try:
            _, compiled, info = lower_combo(arch, shape_name,
                                            multi_pod=multi_pod,
                                            cfg_override=shallow)
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            vals[n] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes": float(ca.get("bytes accessed", 0.0))}
        except Exception as e:
            rec["calib_error"] = f"{type(e).__name__}: {e}"
            break
    if len(vals) == 2:
        N = cfg.n_blocks if cfg.arch_type != "audio" else cfg.n_layers
        for key in ("flops", "bytes"):
            b = vals[2][key] - vals[1][key]
            rec[f"hlo_{key}_corrected"] = vals[1][key] + (N - 1) * b
        rec["calib"] = {"c1": vals[1], "c2": vals[2], "n_units": N}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[calib] {arch}__{shape_name}__{mesh_tag}: "
          f"flops x{rec.get('hlo_flops_corrected', 0) / max(rec.get('hlo_flops', 1), 1):.1f}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--calibrate", action="store_true")
    ap.add_argument("--override", action="append", default=[],
                    help="k=v hillclimb override (attn_chunk/loss_chunk/"
                         "remat/residual/tp_off)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        overrides[k] = (int(v) if v.isdigit() else
                        v == "true" if v in ("true", "false") else v)

    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(specs.INPUT_SHAPES)
    for a in archs:
        for s in shapes:
            if args.calibrate:
                calibrate(a, s, args.multi_pod)
            else:
                run_one(a, s, args.multi_pod, args.force,
                        overrides=overrides or None)


if __name__ == "__main__":
    main()
