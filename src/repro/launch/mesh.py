"""Production mesh construction.

Single pod:  (data=16, model=16)            — 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     — 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests and smoke
runs must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU integration tests (requires matching device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def make_sweep_mesh(n_devices: int | None = None):
    """1-D ``("scenario",)`` mesh over the local devices for embarrassingly
    parallel scenario sweeps (V grids, τ×B grids — every scenario is an
    independent experiment, so the only sharding axis is the grid itself).

    Returns ``None`` on a single device — the sweep drivers
    (``FusedRoundEngine.scan_v_grid``, ``benchmarks/jcsba_solver.py``) take
    that as "fall back to the plain single-device vmap".  Virtual CPU devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) count like real
    ones, which is how the sharded-vs-single parity tests run on CPU."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    n = len(devs) if n_devices is None else min(n_devices, len(devs))
    if n <= 1:
        return None
    return Mesh(np.asarray(devs[:n]), ("scenario",))


def make_population_mesh(n_scenario: int | None = None,
                         n_clients: int | None = None):
    """2-D ``("scenario", "clients")`` mesh for population-scale sweeps: the
    scenario axis fans out independent experiments (as in ``make_sweep_mesh``)
    while the clients axis partitions the device-resident client store and
    the per-client randomness, so O(K·N·d) population data scales across
    devices (``launch.sharding.logical_pspec`` + the cohort gather in
    fl/fused_round.py).

    Factor the local device count explicitly (``n_scenario × n_clients``) or
    leave one side None to infer it; with both None all devices go to the
    clients axis (scenario=1).  Returns ``None`` on a single device, like
    ``make_sweep_mesh`` — callers fall back to the unsharded vmap."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    total = len(devs)
    if total <= 1:
        return None
    if n_scenario is None and n_clients is None:
        n_scenario, n_clients = 1, total
    elif n_clients is None:
        n_clients = total // n_scenario
    elif n_scenario is None:
        n_scenario = total // n_clients
    n = n_scenario * n_clients
    if n_scenario < 1 or n_clients < 1 or n > total:
        raise ValueError(
            f"mesh {n_scenario}x{n_clients} needs {n} devices, "
            f"have {total}")
    return Mesh(np.asarray(devs[:n]).reshape(n_scenario, n_clients),
                ("scenario", "clients"))


def data_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axes(mesh) -> tuple:
    """Axes FSDP-style parameter sharding uses (ZeRO over all data replicas;
    on the multi-pod mesh this includes the pod axis so kimi-k2-scale
    optimizer state fits — DESIGN.md §6)."""
    return data_axes(mesh)


def n_data_shards(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
