"""Production mesh construction.

Single pod:  (data=16, model=16)            — 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     — 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax import; tests and smoke
runs must keep seeing 1 CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 1, n_model: int = 1):
    """Tiny mesh for CPU integration tests (requires matching device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes the global batch is sharded over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def fsdp_axes(mesh) -> tuple:
    """Axes FSDP-style parameter sharding uses (ZeRO over all data replicas;
    on the multi-pod mesh this includes the pod axis so kimi-k2-scale
    optimizer state fits — DESIGN.md §6)."""
    return data_axes(mesh)


def n_data_shards(mesh) -> int:
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
