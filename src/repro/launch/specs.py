"""Input / cache ShapeDtypeStruct specs for every (arch x input-shape) pair.

No device memory is ever allocated here — everything is ``ShapeDtypeStruct``
stand-ins consumed by ``jit(...).lower()``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from ..models import transformer as T
from ..models import encdec
from .mesh import data_axes

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k":    InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k":  InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k":   InputShape("long_500k", 524288, 1, "decode"),
}

# whisper's encoder source length (30 s of 10 ms frames, post-conv: 1500)
WHISPER_SRC_LEN = 1536
# llava anyres tiling: 4 tiles + base image, 576 patches each
VLM_N_PATCHES = 2880

# archs with full quadratic attention and no sub-quadratic variant skip
# long_500k (DESIGN.md §5); gemma3 (sliding window), jamba + mamba2
# (SSM state) run it.
LONG_CONTEXT_OK = {"gemma3-12b", "jamba-v0.1-52b", "mamba2-370m"}


def supports(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_OK:
        return False, ("full quadratic attention; no sub-quadratic variant "
                       "implemented for this family")
    return True, ""


# ---------------------------------------------------------------------------
def batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs of the step inputs (excluding params/opt/cache)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = {"tokens": SDS((B, S), jnp.int32)}
        if shape.kind == "train":
            batch["labels"] = SDS((B, S), jnp.int32)
        if cfg.arch_type == "vlm":
            batch["patches"] = SDS((B, VLM_N_PATCHES, cfg.frontend_dims[0]),
                                   jnp.bfloat16)
        if cfg.arch_type == "audio":
            batch["src_embeds"] = SDS((B, WHISPER_SRC_LEN, cfg.d_model),
                                      jnp.bfloat16)
        return batch
    # decode: one new token against a seq_len cache
    return {"token": SDS((B, 1), jnp.int32),
            "index": SDS((), jnp.int32)}


def batch_pspecs(cfg: ModelConfig, shape: InputShape, mesh) -> dict:
    da = data_axes(mesh)
    bspec = da if shape.global_batch > 1 else None
    if shape.kind in ("train", "prefill"):
        out = {"tokens": P(bspec, None)}
        if shape.kind == "train":
            out["labels"] = P(bspec, None)
        if cfg.arch_type == "vlm":
            out["patches"] = P(bspec, None, None)
        if cfg.arch_type == "audio":
            out["src_embeds"] = P(bspec, None, None)
        return out
    return {"token": P(bspec, None), "index": P()}


# ---------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, shape: InputShape):
    """ShapeDtypeStructs for the decode cache (eval_shape of init_cache)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.arch_type == "audio":
        return jax.eval_shape(
            lambda: encdec.init_dec_cache(cfg, B, S, WHISPER_SRC_LEN))
    return jax.eval_shape(lambda: T.init_cache(cfg, B, S))


def cache_pspecs(cache_shape, cfg: ModelConfig, shape: InputShape, mesh):
    """KV caches: batch over data when B>1; kv-heads over model when they
    divide it, otherwise the sequence dim takes the model axis (all assigned
    archs have GQA kv=8 < 16, so seq-sharded caches are the norm — the decode
    softmax then reduces over a sharded axis, which XLA turns into the
    expected all-reduce, visible in the roofline's collective term).
    long_500k (B=1) additionally spreads seq over the data axes."""
    da = data_axes(mesh)
    batch_first = shape.global_batch > 1
    n_model = mesh.shape["model"]
    kv_div = cfg.n_kv_heads > 0 and cfg.n_kv_heads % n_model == 0

    def spec_for(path: str, leaf) -> P:
        nd = leaf.ndim
        if path.endswith(("/k", "/v")) or "cross_" in path:
            # [n_blocks(?), B, S, K, hd]
            if kv_div:
                s = (None, da if batch_first else None,
                     None if batch_first else da, "model", None)
            elif batch_first:
                s = (None, da, "model", None, None)
            else:
                s = (None, None, tuple(da) + ("model",), None, None)
            return P(*s[-nd:]) if nd <= 5 else P(*((None,) * (nd - 5) + s))
        if path.endswith("/ssm"):
            # [n_blocks, B, nh, N, hp]
            s = (None, da if batch_first else None, "model", None, None)
            return P(*s[-nd:])
        if "conv_x" in path:
            s = (None, da if batch_first else None, None, "model")
            return P(*s[-nd:])
        if "conv_" in path:
            s = (None, da if batch_first else None, None, None)
            return P(*s[-nd:])
        return P(*((None,) * nd))

    from .sharding import _path_str, sanitize_tree
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    specs = [spec_for(_path_str(p), leaf) for p, leaf in flat]
    return sanitize_tree(jax.tree_util.tree_unflatten(treedef, specs),
                         cache_shape, mesh)
