"""Flat donated parameter buffers: pack a params pytree into one contiguous
1-D buffer per dtype + static unpack metadata.

Why: the serving hot path wants a round-boundary params hot-swap to be ONE
donated device copy, not a pytree of hundreds of small transfers.  A
``ParamSpec`` freezes the tree structure and every leaf's (path, shape,
dtype, offset); ``pack`` is a per-dtype ``jnp.concatenate`` of the raveled
leaves (reduced configs are all-float32, so literally one buffer) and
``unpack`` is static slices + reshapes that XLA folds into views — a jitted
decode step reading params through ``unpack(bufs, spec)`` touches the same
bytes as one reading the pytree, with zero per-leaf dispatch.

``make_swap(spec)`` jits the pack with the OLD buffers donated: XLA aliases
the donated inputs to the (shape/dtype-identical) outputs, so the
concatenate writes the fresh params straight into the old allocation —
steady-state serving never allocates on a swap.  ``pack_np`` is the host
mirror of the same layout, reused by ``checkpoint.save_flat_checkpoint``.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class LeafSpec(NamedTuple):
    path: str                    # '/'-joined key path (checkpoint convention)
    shape: Tuple[int, ...]
    dtype: str                   # canonical dtype name, e.g. "float32"
    offset: int                  # element offset into this dtype's buffer


class ParamSpec(NamedTuple):
    """Static (hashable) layout of a packed pytree."""
    treedef: Any                             # jax PyTreeDef
    leaves: Tuple[LeafSpec, ...]             # in tree_flatten order
    sizes: Tuple[Tuple[str, int], ...]       # (dtype name, total elements)

    @property
    def n_buffers(self) -> int:
        return len(self.sizes)

    def nbytes(self) -> int:
        return sum(n * _np_dtype(dt).itemsize for dt, n in self.sizes)


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:            # ml_dtypes types (bfloat16, float8_*, ...)
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(p.key) if hasattr(p, "key")
                     else f"#{getattr(p, 'idx', p)}")
    return "/".join(parts)


def _leaf_size(shape: Tuple[int, ...]) -> int:
    return int(np.prod(shape, dtype=np.int64)) if shape else 1


def spec_of(tree) -> ParamSpec:
    """Freeze ``tree``'s layout.  Works on arrays or ShapeDtypeStructs."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    offsets: Dict[str, int] = {}
    leaves = []
    for path, leaf in flat:
        dt = jnp.result_type(leaf).name
        shape = tuple(np.shape(leaf))
        off = offsets.get(dt, 0)
        leaves.append(LeafSpec(_path_str(path), shape, dt, off))
        offsets[dt] = off + _leaf_size(shape)
    return ParamSpec(treedef, tuple(leaves), tuple(sorted(offsets.items())))


def pack(tree, spec: ParamSpec = None) -> Dict[str, jax.Array]:
    """tree -> {dtype name: 1-D device buffer}, leaves in flatten order."""
    if spec is None:
        spec = spec_of(tree)
    groups: Dict[str, list] = {}
    for ls, leaf in zip(spec.leaves, jax.tree_util.tree_leaves(tree)):
        groups.setdefault(ls.dtype, []).append(
            jnp.asarray(leaf, dtype=ls.dtype).reshape(-1))
    return {dt: (jnp.concatenate(groups[dt]) if len(groups[dt]) > 1
                 else groups[dt][0])
            for dt, _ in spec.sizes}


def unpack(bufs: Dict[str, jax.Array], spec: ParamSpec):
    """{dtype: buffer} -> the original pytree (static slices + reshapes)."""
    leaves = []
    for ls in spec.leaves:
        n = _leaf_size(ls.shape)
        seg = jax.lax.slice_in_dim(bufs[ls.dtype], ls.offset, ls.offset + n)
        leaves.append(seg.reshape(ls.shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def pack_np(tree, spec: ParamSpec = None):
    """Host-side pack into numpy buffers (the checkpoint flat layout).

    Returns ``(bufs, spec)`` with the identical element layout as ``pack``.
    """
    if spec is None:
        spec = spec_of(tree)
    bufs = {dt: np.empty(n, dtype=_np_dtype(dt)) for dt, n in spec.sizes}
    for ls, leaf in zip(spec.leaves, jax.tree_util.tree_leaves(tree)):
        n = _leaf_size(ls.shape)
        bufs[ls.dtype][ls.offset:ls.offset + n] = \
            np.asarray(leaf).astype(_np_dtype(ls.dtype), copy=False) \
              .reshape(-1)
    return bufs, spec


def unpack_np(bufs: Dict[str, np.ndarray], spec: ParamSpec):
    """Host-side inverse of ``pack_np`` (no device transfer)."""
    leaves = []
    for ls in spec.leaves:
        n = _leaf_size(ls.shape)
        leaves.append(bufs[ls.dtype][ls.offset:ls.offset + n]
                      .reshape(ls.shape))
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def make_swap(spec: ParamSpec):
    """Jitted ``(old_bufs, new_tree) -> new_bufs`` with the old buffers
    donated — the hot-swap primitive.  Each leaf is written into its static
    offset of the donated buffer via ``dynamic_update_slice``; because the
    input is donated and dead after the first write, XLA performs every
    update in place — the swap is one pass over the params into the old
    allocation, zero new allocations at steady state."""
    def _swap(old_bufs, tree):
        bufs = dict(old_bufs)
        for ls, leaf in zip(spec.leaves, jax.tree_util.tree_leaves(tree)):
            seg = jnp.asarray(leaf, dtype=ls.dtype).reshape(-1)
            bufs[ls.dtype] = jax.lax.dynamic_update_slice_in_dim(
                bufs[ls.dtype], seg, ls.offset, axis=0)
        return bufs
    return jax.jit(_swap, donate_argnums=(0,))
