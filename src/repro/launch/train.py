"""Training driver.

Modes:
* ``standard`` — data-parallel LM training of any registered arch.  On this
  CPU container use ``--reduced`` (2-block, tiny-dim variant of the same
  family); on a real TPU slice drop the flag and the production mesh +
  shardings from the dry-run path are used unchanged.
* ``federated`` — the paper's wireless-MFL loop (Algorithm 1) with
  pods-as-clients semantics: each FL client holds a shard of the token stream
  and the JCSBA scheduler decides which "pods" participate each round under
  the simulated wireless constraints.  (The faithful paper experiment with
  the LSTM/CNN models lives in examples/wireless_mfl.py.)

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \\
      --steps 50 --batch 8 --seq 256
  PYTHONPATH=src python -m repro.launch.train --mode federated --rounds 40
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.tokens import TokenStream, vlm_batch
from ..optim import warmup_cosine, adamw
from . import steps as S


def train_standard(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] arch={cfg.name} reduced={args.reduced} "
          f"layers={cfg.n_layers} d_model={cfg.d_model}")
    params = S.init_fn(cfg)(jax.random.key(args.seed))
    n_params = S.param_count(params)
    print(f"[train] params: {n_params/1e6:.2f}M")
    opt = adamw(warmup_cosine(args.lr, 10, args.steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(S.make_train_step(cfg, opt, n_groups=1,
                                        attn_chunk=min(256, args.seq)))
    stream = TokenStream(cfg.vocab_size, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    losses = []
    for i in range(args.steps):
        if cfg.arch_type == "vlm":
            batch = vlm_batch(rng, args.batch, args.seq, 16,
                              cfg.frontend_dims[0], cfg.vocab_size)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
        else:
            b = stream.batch(args.batch, args.seq)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.arch_type == "audio":
                batch["src_embeds"] = jnp.asarray(rng.normal(
                    size=(args.batch, 64, cfg.d_model)).astype(np.float32))
        t0 = time.time()
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if i % max(args.steps // 10, 1) == 0 or i == args.steps - 1:
            print(f"[train] step {i:4d} loss={float(loss):.4f} "
                  f"({time.time() - t0:.2f}s)")
    assert np.isfinite(losses).all(), "NaN loss"
    print(f"[train] first->last loss: {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


def train_federated(args):
    from ..fl.runtime import MFLExperiment
    exp = MFLExperiment(dataset=args.dataset, scheduler=args.scheduler,
                        n_samples=args.n_samples, seed=args.seed, V=args.V)
    exp.run(args.rounds, verbose=True)
    print("[federated] final:", exp.final_metrics())
    return exp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="standard",
                    choices=["standard", "federated"])
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    # federated
    ap.add_argument("--dataset", default="crema_d")
    ap.add_argument("--scheduler", default="jcsba")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--n-samples", type=int, default=800)
    ap.add_argument("--V", type=float, default=1.0)
    args = ap.parse_args()
    if args.mode == "federated":
        train_federated(args)
    else:
        train_standard(args)


if __name__ == "__main__":
    main()
