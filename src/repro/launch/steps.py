"""Per-architecture step factories: init / train_step / prefill / serve_step.

These are the functions the dry-run lowers and the drivers jit.  Optimizer
selection is memory-aware: Adafactor for ≥30B-parameter architectures
(factored second moments — DESIGN.md §5 kimi-k2 note), AdamW otherwise.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fusion
from ..models import encdec, multimodal, transformer as T
from ..models.config import ModelConfig
from ..optim import adafactor, adamw, apply_updates
from .specs import WHISPER_SRC_LEN

ADAFACTOR_THRESHOLD = 30e9


def init_fn(cfg: ModelConfig) -> Callable:
    if cfg.arch_type == "audio":
        return lambda key: encdec.init_params(key, cfg)
    if cfg.arch_type == "vlm":
        return lambda key: multimodal.init_vlm_params(key, cfg)
    return lambda key: T.init_params(key, cfg)


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(init_fn(cfg), jax.random.key(0))


def param_count(shapes) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)))


def make_optimizer(cfg: ModelConfig, n_params: Optional[int] = None,
                   lr: float = 1e-4):
    if n_params is None:
        n_params = param_count(params_shape(cfg))
    if n_params >= ADAFACTOR_THRESHOLD:
        return adafactor(lr), "adafactor"
    return adamw(lr), "adamw"


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------
def make_loss_fn(cfg: ModelConfig, *, n_groups: int = 1,
                 attn_chunk: int = 1024, aux_weight: float = 0.01, **bk):
    """Extra keyword levers (threaded to the backbone — §Perf hillclimbs):
    ``loss_chunk``: fused chunked unembed+CE; ``residual_spec``: sharding
    constraint on the residual stream; ``remat``: checkpoint super-blocks."""
    if cfg.arch_type == "vlm":
        loss_chunk = bk.pop("loss_chunk", None)
        if loss_chunk:
            def loss(params, batch):
                total, aux = multimodal.vlm_loss_chunked(
                    params, batch, cfg, loss_chunk, n_groups=n_groups,
                    attn_chunk=attn_chunk, **bk)
                return total + aux_weight * aux
            return loss

        def loss(params, batch):
            modal, aux = multimodal.vlm_modal_logits(
                params, batch, cfg, n_groups=n_groups, attn_chunk=attn_chunk,
                **bk)
            total, _ = fusion.multimodal_loss(modal, batch["labels"])
            return total + aux_weight * aux
        return loss
    if cfg.arch_type == "audio":
        bk.pop("loss_chunk", None)

        def loss(params, batch):
            enc = encdec.encode(params, batch["src_embeds"], cfg,
                                attn_chunk=attn_chunk)
            dec_logits = encdec.decode_fwd(params, batch["tokens"], enc, cfg,
                                           attn_chunk=attn_chunk)
            audio_logits = encdec.audio_head_logits(params, enc)[:, None, :]
            total, _ = fusion.multimodal_loss(
                {"text": dec_logits, "audio": audio_logits}, batch["labels"])
            return total
        return loss

    def loss(params, batch):
        return T.loss_fn(params, batch, cfg, n_groups=n_groups,
                         attn_chunk=attn_chunk, aux_weight=aux_weight, **bk)
    return loss


def make_train_step(cfg: ModelConfig, optimizer, *, n_groups: int = 1,
                    attn_chunk: int = 1024, **bk):
    loss_fn = make_loss_fn(cfg, n_groups=n_groups, attn_chunk=attn_chunk,
                           **bk)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss

    return train_step


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, *, n_groups: int = 1,
                      attn_chunk: int = 1024, **bk):
    bk.pop("loss_chunk", None)
    if cfg.arch_type == "vlm":
        def prefill(params, batch):
            fused, _, _ = multimodal.vlm_fused_forward(
                params, batch, cfg, n_groups=n_groups, attn_chunk=attn_chunk,
                **bk)
            return fused[:, -1, :]
        return prefill
    if cfg.arch_type == "audio":
        def prefill(params, batch):
            enc = encdec.encode(params, batch["src_embeds"], cfg,
                                attn_chunk=attn_chunk)
            logits = encdec.decode_fwd(params, batch["tokens"], enc, cfg,
                                       attn_chunk=attn_chunk)
            return logits[:, -1, :]
        return prefill

    def prefill(params, batch):
        return T.prefill(params, batch["tokens"], cfg, n_groups=n_groups,
                         attn_chunk=attn_chunk, **bk)
    return prefill


def make_bulk_prefill(cfg: ModelConfig, *, n_groups: int = 1,
                      attn_chunk: int = 1024):
    """Bulk prefill-with-cache-export: the whole prompt in one chunked pass.

    Dense/ssm/moe archs: ``(params, tokens [B,S], cache) ->
    (next_token [B,1], filled cache)``; audio archs take the encoder output
    too: ``(params, tokens, enc, cache)``.  The returned cache is positioned
    at ``index=S`` — exactly what S teacher-forced ``serve_step`` calls
    would have produced (tests/test_decode_consistency.py), at a fraction of
    the dispatches (``benchmarks/serving.py`` measures the speedup).
    """
    if cfg.arch_type == "audio":
        def bulk_prefill(params, tokens, enc, cache):
            logits, cache = encdec.prefill_with_cache(
                params, tokens, enc, cache, cfg, attn_chunk=attn_chunk)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None], \
                cache
        return bulk_prefill

    def bulk_prefill(params, tokens, cache):
        logits, cache = T.prefill_with_cache(params, tokens, cache, cfg,
                                             n_groups=n_groups,
                                             attn_chunk=attn_chunk)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None], cache
    return bulk_prefill


def make_serve_step(cfg: ModelConfig):
    """One greedy decode step: (params, cache, token, index) ->
    (next_token [B,1], new_cache).

    VLM note: the vision decision head contributes a per-request constant
    logit bias during decode; it is added at the sampling layer by
    ``launch.serve`` (precomputed once at prefill), so the per-step function
    is the backbone decode for both dense and vlm archs.
    """
    if cfg.arch_type == "audio":
        def serve_step(params, cache, token, index):
            logits, cache = encdec.decode_step(params, cache, token, index, cfg)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
        return serve_step

    def serve_step(params, cache, token, index):
        logits, cache = T.decode_step(params, cache, token, index, cfg)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
    return serve_step
