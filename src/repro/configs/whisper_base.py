"""whisper-base [audio] — encoder-decoder; mel+conv frontend STUBBED to frame
embeddings (carve-out). [arXiv:2212.04356]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", arch_type="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    is_encoder_decoder=True, encoder_layers=6,
    modalities=("audio", "text"),
    source="[arXiv:2212.04356] Whisper",
)
