"""qwen3-0.6b [dense] — qk_norm, GQA kv=8. [hf:Qwen/Qwen3-8B family]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", arch_type="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    qk_norm=True,
    source="[hf:Qwen/Qwen3-8B family card]",
)
