"""Architecture registry: --arch <id> resolves here."""
from . import (gemma3_12b, jamba_v01_52b, kimi_k2_1t_a32b,
               llama4_scout_17b_a16e, llava_next_34b, mamba2_370m,
               qwen2_72b, qwen3_0_6b, qwen3_4b, whisper_base)

ARCHS = {
    "gemma3-12b": gemma3_12b.CONFIG,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.CONFIG,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e.CONFIG,
    "jamba-v0.1-52b": jamba_v01_52b.CONFIG,
    "llava-next-34b": llava_next_34b.CONFIG,
    "qwen2-72b": qwen2_72b.CONFIG,
    "qwen3-0.6b": qwen3_0_6b.CONFIG,
    "mamba2-370m": mamba2_370m.CONFIG,
    "whisper-base": whisper_base.CONFIG,
    "qwen3-4b": qwen3_4b.CONFIG,
}


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]
