"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt family; 12B sizing per Gemma 3 tech report]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", arch_type="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    qk_norm=True, sliding_window=1024, local_global_ratio=5,
    rope_theta=1_000_000.0,
    source="[hf:google/gemma-3-1b-pt]; Gemma 3 technical report",
)
