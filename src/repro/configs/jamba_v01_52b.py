"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2 on
every other layer. [arXiv:2403.19887]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", arch_type="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    n_experts=16, top_k=2, expert_d_ff=14336, moe_every=2,
    attn_every=8, ssm_state=16, ssm_head_dim=64, ssm_expand=2,
    source="[arXiv:2403.19887] Jamba v0.1",
)
