"""The paper's own IEMOCAP multimodal model (audio LSTM + text LSTM, §VI)."""
DATASET = "iemocap"
MODALITIES = ("audio", "text")
N_CLASSES = 10
