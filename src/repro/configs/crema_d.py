"""The paper's own CREMA-D multimodal model (audio LSTM + image CNN, §VI)."""
DATASET = "crema_d"
MODALITIES = ("audio", "image")
N_CLASSES = 6
