"""kimi-k2-1t-a32b [moe] — trillion-param MoE, 384 experts top-8 + 1 shared.
[arXiv:2501.kimi2 paper-table]. Assigned spec: GQA kv=8, per-expert d_ff=2048."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    n_experts=384, top_k=8, expert_d_ff=2048, n_shared_experts=1,
    source="[arXiv:2501.kimi2] Kimi K2 paper table",
)
