"""qwen3-4b [dense] — qk_norm, GQA kv=8. [hf:Qwen/Qwen3-8B family]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", arch_type="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=9728, vocab_size=151936,
    qk_norm=True,
    source="[hf:Qwen/Qwen3-8B family card]",
)
