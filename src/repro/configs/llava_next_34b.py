"""llava-next-34b [vlm] — anyres tiling; vision frontend STUBBED to patch
embeddings (carve-out), decision-level fusion head per the paper.
[hf:llava-hf/llava-v1.6-mistral-7b-hf family, 34B sizing]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", arch_type="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    modalities=("text", "vision"), frontend_dims=(1024,),
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf] (34B sizing)",
)
