"""qwen2-72b [dense] — GQA kv=8, QKV bias. [arXiv:2407.10671]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b", arch_type="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True,
    source="[arXiv:2407.10671] Qwen2",
)
