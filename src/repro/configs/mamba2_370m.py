"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m", arch_type="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    source="[arXiv:2405.21060] Mamba2 SSD",
)
