"""Fully on-device MFL rounds — schedule → cohort gather → local updates →
Eq. 12 aggregation → queue/tracker update as ONE jitted program per round.

PR 1 batched the client fan-out (fl/client.py) and PR 2 batched the server
decision layer (wireless/solver/), but the runtime still hopped to host
between them every round: solver jit → host decode → client jit → host
aggregation → host trackers.  This module chains all four stages inside a
single ``round_step(carry, xs) -> (carry, aux)`` whose carry packs the entire
evolving experiment state, so ``lax.scan`` can drive whole experiments (and,
vmapped, dense V/τ scenario grids — benchmarks/fused_round.py) without
leaving the device.

Cohort gather — the BGD hot path is O(J), not O(K)
--------------------------------------------------
Originally the round ran the masked BGD update over the *whole* dense client
stack: every round touched K × max_batch × d features even though only a
handful of clients are ever scheduled.  Policies now emit a static-size,
duplicate-free cohort index vector (``wireless.policies.cohort_indices`` —
the sixth ``step_full`` output), and the round body *gathers* exactly those
J rows from a device-resident ``data.partition.ClientStore`` before the BGD
stage:

* single device — ``store.take(idx)`` (``jnp.take`` over the client axis);
* client-sharded 2-D mesh — a masked cross-shard reduction (``_gather_rows``):
  each shard contributes the cohort rows it owns, ``lax.psum`` over the
  ``"clients"`` axis reassembles them bit-exactly.

Everything model-sized downstream — the vmapped BGD, the Eq. 12 contraction
(``core.aggregation``), the ζ/δ divergence norms (Gram-form
``core.convergence.tracker_update_gram``) — runs on [J]-leading stacks;
cohort-local results are scattered back to dense [K] rows through the index
vector (a ``segment_sum``, exact because the indices are duplicate-free).
Only O(K) *vector* physics stays dense: channel rates, latency feasibility,
Lyapunov queues — cheap at any K.  Per-round latency and peak memory
therefore scale with the cohort, not the population
(benchmarks/population_scale.py: K = 50 → 100 000 at J ≈ 10).

Carry layout (``FusedCarry``, a pytree):

* ``params``      — the global multimodal model {modality: subtree};
* ``policy``      — the scheduling policy's own state dict
  (``wireless.policies``: JCSBA's warm-start antibody, Round-Robin's cursor,
  empty for Random/Selection) — the engine is policy-generic: any scheduler
  exposing a traced ``SchedulePolicy`` core runs fused;
* ``Q`` / ``spent`` — Lyapunov virtual energy queues + cumulative energy;
* ``zeta`` / ``delta`` — the Theorem-1 ζ_m / δ_{k,m} trackers as dense
  [M] / [M, K] arrays (modality order = ``BoundState.mods``);
* ``model_dist``  — ‖θ_k − θ⁰‖ bookkeeping (read by the Selection policy).

Per-round inputs (``RoundXs``) are the only randomness the loop consumes:
channel gains, the immune-search PRNG seed and per-client dropout seeds —
plus the (deterministic) ``eval_flag`` marking rounds on the ``eval_every``
grid.  They are pregenerated on host by ``draw_round_xs`` in exactly the
order the host loop consumes its ``np.random.Generator`` stream (channel
draws → solver seed → K client seeds — see
``MFLExperiment._draw_client_seeds``), which is what makes the fused path
draw-for-draw equivalent to the host reference: with identical experiment
seeds, participant sets match exactly and params / queues / trackers match
to float32 reduction-order tolerance (tests/test_fused_round.py locks this
contract).

Two per-round decision surfaces ride along since PR 5:

* **modality dropout** — policies whose ``step_full`` emits a drop mask
  ([28]'s baseline, ``wireless.policies.DropoutPolicy``) thread it into the
  Eq. 12 upload masks (``core.aggregation.upload_masks_traced``), so the
  last host-only scheduler now scans on device and the full Table-3
  five-policy comparison is one fused program;
* **device-resident eval** — rounds flagged by ``xs.eval_flag`` evaluate the
  freshly aggregated globals on the held-out split inside the scan
  (``fl.eval.eval_metrics`` behind ``lax.cond``; skipped rounds emit NaN
  fillers gated by ``RoundAux.eval_mask``), so ``run_scanned`` and
  ``scan_v_grid`` produce multimodal + unimodal accuracy *curves* with zero
  host eval calls.

Equivalence caveats (all covered by the tests' tolerances): the host loop
keeps queues/trackers in float64 numpy between the f32 jitted stages, while
the fused carry stays f32 end-to-end — per-round drift is ~1e-7 relative and
does not move the solver's argmin on the tested configs.  The cohort path
adds no new caveat: cohort rows appear in ascending client order (stable
argsort), so reductions see the same nonzero terms in the same order as the
dense masked path, and interleaved exact zeros do not move f32 sums
(property-tested in tests/test_cohort_gather.py).
"""
from __future__ import annotations

import functools
import time
import warnings
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..core import aggregation as agg
from ..core.convergence import grad_gram, tracker_update_gram
from .eval import device_test_set, eval_metrics, nan_metrics
from ..launch.mesh import make_sweep_mesh
from ..launch.sharding import (logical_pspec, pad_leading_axis,
                               population_shard_map, scenario_shard_map,
                               slice_leading_axis)
from ..wireless.lyapunov import queue_update
from ..wireless.solver import build_solver_data
from ..wireless.solver.common import B_LO
from ..wireless.solver.jaxsolver import _bmin, rate, to_device


class FusedCarry(NamedTuple):
    """Whole-experiment state threaded through ``lax.scan``."""
    params: Dict[str, Any]
    policy: Dict[str, jax.Array]    # SchedulePolicy state (may be empty)
    Q: jax.Array                # [K]
    spent: jax.Array            # [K]
    zeta: jax.Array             # [M]
    delta: jax.Array            # [M, K]
    model_dist: jax.Array       # [K]


class RoundXs(NamedTuple):
    """Pregenerated per-round randomness (stack leading axis to scan)."""
    h: jax.Array                # [K] channel gains (float32)
    draw_seed: jax.Array        # scalar uint32 — immune-search key seed
    client_seeds: jax.Array     # [K] uint32 — per-client dropout seeds
    eval_flag: jax.Array        # scalar bool — evaluate this round's globals


class RoundAux(NamedTuple):
    """Per-round outputs — the traced stand-in for ScheduleDecision +
    RoundRecord, decoded on host by ``MFLExperiment._decode_fused_round``."""
    a: jax.Array                # [K] bool — scheduled (incl. failures)
    ok: jax.Array               # [K] bool — participated
    J: jax.Array                # scalar solver objective J₂(a*)
    weights: Dict[str, jax.Array]   # Eq. 12 weights w^t_{k,m}
    energy_total: jax.Array     # scalar Σ_k cumulative energy after round
    drop: Dict[str, jax.Array]  # {m: [K] bool} — modality dropped this round
    metrics: Dict[str, jax.Array]   # test metrics (NaN when not evaluated)
    eval_mask: jax.Array        # scalar bool — ``metrics`` is real


def draw_round_xs(exp, rounds: int, eval_every: Optional[int] = None,
                  include_final: bool = False) -> RoundXs:
    """Consume ``rounds`` rounds of the experiment's host randomness in the
    canonical order — one host-loop round exactly: K channel draws
    (``Channel.draw``), one policy seed (the single ``rng.integers(2 ** 31)``
    every policy-backed scheduler draws per round, whatever the policy), then
    the per-client dropout seeds via the experiment's own
    ``_draw_client_seeds`` so that contract stays single-sourced.  A fused
    experiment and a host-loop experiment sharing the same seed therefore
    walk the identical ``np.random`` stream.

    ``eval_flag`` is deterministic, not random: round t is flagged exactly
    when the host loop would evaluate it (``(exp._round + t) %
    exp.eval_every == 0``).  ``include_final`` additionally flags the last
    round — sweep drivers use it so every scenario's curve ends with the
    final model's metrics whatever the cadence.

    ``eval_every`` is deprecated: the cadence is the *experiment's* setting,
    duplicated here it silently desynchronised host-loop and fused curves.
    Pass ``MFLExperiment(eval_every=...)`` instead."""
    if eval_every is not None:
        warnings.warn(
            "draw_round_xs(eval_every=...) is deprecated; the eval cadence "
            "comes from the experiment — construct "
            "MFLExperiment(eval_every=...) instead",
            DeprecationWarning, stacklevel=2)
    K = exp.params.K
    ee = int(exp.eval_every if eval_every is None else eval_every)
    h = np.empty((rounds, K), np.float32)
    draw = np.empty(rounds, np.uint32)
    cseed = np.empty((rounds, K), np.uint32)
    flags = np.zeros(rounds, bool)
    for t in range(rounds):
        h[t] = exp.channel.draw()
        draw[t] = exp.rng.integers(2 ** 31)
        cseed[t] = exp._draw_client_seeds()
        flags[t] = (exp._round + t) % ee == 0
    if include_final and rounds:
        flags[-1] = True
    return RoundXs(jnp.asarray(h), jnp.asarray(draw), jnp.asarray(cseed),
                   jnp.asarray(flags))


def draw_population_xs(channel, rng, K: int, rounds: int,
                       eval_every: int = 0,
                       include_final: bool = False) -> RoundXs:
    """``draw_round_xs`` for ``from_store`` engines (no ``MFLExperiment``):
    one host-loop round of randomness per scanned round — K channel draws,
    one policy seed, K client seeds — from an explicit ``Channel`` + numpy
    generator.  ``eval_every <= 0`` disables the eval cadence entirely
    (``include_final`` can still flag the last round, the scenario-zoo
    convention so every curve ends with the final model's metrics)."""
    h = np.empty((rounds, K), np.float32)
    draw = np.empty(rounds, np.uint32)
    cseed = np.empty((rounds, K), np.uint32)
    flags = np.zeros(rounds, bool)
    for t in range(rounds):
        h[t] = channel.draw()
        draw[t] = rng.integers(2 ** 31)
        cseed[t] = rng.integers(2 ** 31, size=K, dtype=np.uint32)
        flags[t] = eval_every > 0 and t % eval_every == 0
    if include_final and rounds:
        flags[-1] = True
    return RoundXs(jnp.asarray(h), jnp.asarray(draw), jnp.asarray(cseed),
                   jnp.asarray(flags))


def _gather_rows(x, idx, axis_name: str):
    """Cross-shard cohort gather under a client-sharded mesh.

    ``x`` is this shard's [K_loc, ...] slice of a client-axis leaf; ``idx``
    [J] holds *global* client indices (replicated).  Each shard contributes
    the rows it owns (others zeroed), and ``lax.psum`` over the mesh axis
    reassembles the full cohort — exact for every dtype here: each output
    element receives exactly one nonzero contribution."""
    K_loc = x.shape[0]
    off = lax.axis_index(axis_name) * K_loc
    local = idx - off
    mine = (local >= 0) & (local < K_loc)
    rows = jnp.take(x, jnp.clip(local, 0, K_loc - 1), axis=0)
    orig = rows.dtype
    if orig == jnp.bool_:
        rows = rows.astype(jnp.int32)
    shape = (idx.shape[0],) + (1,) * (rows.ndim - 1)
    rows = jnp.where(mine.reshape(shape), rows, 0)
    out = lax.psum(rows, axis_name)
    return out.astype(orig) if orig == jnp.bool_ else out


class FusedRoundEngine:
    """Per-experiment compiler/runner for the fused round program.

    Built lazily by ``MFLExperiment`` (engine="fused").  Holds the static,
    device-resident context — the ``ClientStore`` population, per-client
    costs, solver template, tracker constants, the held-out test split for
    the in-scan eval — and exposes:

    * ``step(carry, xs)``  — one jitted round;
    * ``scan(carry, xs)``  — R rounds under one ``lax.scan`` (xs stacked);
    * ``init_carry()`` / ``export_carry()`` — host-state ↔ carry conversion.

    ``from_store`` builds an engine straight from a ``ClientStore`` +
    ``WirelessParams`` + policy — no ``MFLExperiment`` (whose per-client
    Python loops are prohibitive at K = 10⁵); benchmarks/population_scale.py
    drives cohort rounds at population scale through it.

    ``trace_count`` increments each time the round body is *traced* — the
    zero-host-round-trips contract is asserted as "many rounds, one trace"
    in tests/test_fused_round.py.
    """

    def __init__(self, exp):
        exp.scheduler.bind(exp.params.K, exp.client_mods)
        self.policy = exp.scheduler.policy
        if self.policy is None:
            raise ValueError(
                f"fused rounds require a traced scheduling policy "
                f"(wireless.policies); scheduler {exp.scheduler.name!r} "
                f"runs host-side only")
        self.exp = exp
        self.K = exp.params.K
        self.mods = list(exp.bound.mods)
        self.V = getattr(exp.scheduler, "V", 1.0)
        self.staleness = float(exp.bound.staleness)
        self.trace_count = 0

        # solver-data template: static entries live on device once; Q/h and
        # the ζ²/δ² snapshot are overwritten from the carry every round
        tmpl = build_solver_data(np.zeros(self.K), np.zeros(self.K),
                                 exp.cost, exp.params, exp.bound, self.V)
        # tau_cmp rides in the template (not a baked engine static) so
        # scenario grids can override it per scenario like every other
        # per-client cost vector
        tmpl["tau_cmp"] = np.asarray(exp.cost.tau_cmp, np.float64)
        self._solver_tmpl = to_device(tmpl)
        self._has = self._solver_tmpl["has"]            # [M, K] bool
        self._D = self._solver_tmpl["D"]                # [K] f32
        self._tau_cmp = self._solver_tmpl["tau_cmp"]
        self._e_cmp = self._solver_tmpl["e_cmp"]
        p = exp.params
        self._tau_max = float(p.tau_max)
        self._E_add = float(p.E_add)
        self._p_tx = float(p.p_tx)
        self._N0 = float(p.N0)

        self._store = exp._get_store()
        self._init_params = jax.tree.map(jnp.asarray, exp.init_params)
        self._cohort = exp.adapter.cohort_step(tuple(self.mods))
        # the adapter's deterministic forward backs the in-scan eval, so the
        # fused curve matches adapter.evaluate for every model family
        self._eval_logits = exp.adapter.eval_logits

        # device-resident eval context: the held-out split lives on device
        # for the engine's lifetime; rounds flagged by xs.eval_flag run the
        # shared fl.eval.eval_metrics program on the fresh globals
        self._test_feats, self._test_labels = device_test_set(exp.test_ds)
        self._compile()

    @classmethod
    def from_store(cls, store, params, policy, adapter, *, V: float = 1.0,
                   eta: float = 0.05, rho: float = 1.0,
                   staleness: float = 0.9, init_zeta: float = 1.0,
                   init_delta: float = 0.3, seed: int = 0):
        """Engine straight from a ``ClientStore`` — the population-scale
        entry point.  The solver template is assembled from the store's
        vectorized cost/ownership arrays (the same fields
        ``build_solver_data`` derives from ``ClientCost``/``BoundState``,
        whose per-client Python loops this path exists to avoid); tracker
        initials mirror ``BoundState``'s cold-start values.  Use
        ``fresh_carry()`` for the matching initial carry."""
        self = cls.__new__(cls)
        self.exp = None
        self.policy = policy
        self.K = store.K
        self.mods = list(store.modalities)
        self.V = float(V)
        self.staleness = float(staleness)
        self.trace_count = 0
        self._init_zeta, self._init_delta = float(init_zeta), float(init_delta)

        has = np.stack([np.asarray(store.has_modality[m], bool)
                        for m in self.mods])
        sizes = np.asarray(store.sizes, np.float64)
        wbar = agg.stacked_weights(sizes, {m: has[i] for i, m in
                                           enumerate(self.mods)})
        tmpl = {
            "Q": np.zeros(self.K),
            "gamma": np.asarray(store.gamma_bits, np.float64),
            "h": np.zeros(self.K),
            "tau_rem": params.tau_max - np.asarray(store.tau_cmp, np.float64),
            "tau_cmp": np.asarray(store.tau_cmp, np.float64),
            "e_cmp": np.asarray(store.e_cmp, np.float64),
            "B_max": float(params.B_max),
            "p_tx": float(params.p_tx),
            "N0": float(params.N0),
            "V": float(V), "eta": float(eta), "rho": float(rho),
            "zeta2": np.full(len(self.mods), init_zeta ** 2),
            "delta2": np.full((len(self.mods), self.K), init_delta ** 2),
            "wbar": np.stack([wbar[m] for m in self.mods]),
            "has": has,
            "D": sizes,
        }
        self._solver_tmpl = to_device(tmpl)
        self._has = self._solver_tmpl["has"]
        self._D = self._solver_tmpl["D"]
        self._tau_cmp = self._solver_tmpl["tau_cmp"]
        self._e_cmp = self._solver_tmpl["e_cmp"]
        self._tau_max = float(params.tau_max)
        self._E_add = float(params.E_add)
        self._p_tx = float(params.p_tx)
        self._N0 = float(params.N0)

        self._store = jax.tree.map(jnp.asarray, store)
        gp = adapter.init_global(jax.random.key(seed))
        self._global_params0 = gp
        self._init_params = jax.tree.map(jnp.asarray, gp)
        self._cohort = adapter.cohort_step(tuple(self.mods))
        self._eval_logits = adapter.eval_logits
        # eval context: client 0's shard stands in as the held-out split —
        # population benches never flag an eval round, but lax.cond still
        # traces both branches, so the program needs *some* test tensors
        self._test_feats = {m: self._store.features[m][0] for m in self.mods}
        self._test_labels = self._store.labels[0]
        self._compile()
        return self

    def _compile(self):
        # drop-mask row -> engine modality index, for policies with dropout
        # (step_full's mask rows follow policy.drop_mods; empty otherwise)
        self._drop_rows = {m: i for i, m in
                           enumerate(getattr(self.policy, "drop_mods", ()))}
        self._jit_step = jax.jit(self._round_step)
        self._jit_scan = jax.jit(self._scan_steps)
        self._sharded_vsweep_cache = {}     # cache key -> jitted sweep

    # ------------------------------------------------------------------
    # host state ↔ carry
    # ------------------------------------------------------------------
    def init_carry(self) -> FusedCarry:
        exp = self.exp
        f32 = lambda x: jnp.asarray(x, jnp.float32)     # noqa: E731
        return FusedCarry(
            params=jax.tree.map(jnp.asarray, exp.global_params),
            policy={k: jnp.asarray(v)
                    for k, v in exp.scheduler.state().items()},
            Q=f32(exp.queues.Q), spent=f32(exp.queues.spent),
            zeta=f32([exp.bound.zeta[m] for m in self.mods]),
            delta=f32(np.stack([exp.bound.delta[m] for m in self.mods])),
            model_dist=f32(exp.model_dist))

    def fresh_carry(self) -> FusedCarry:
        """Cold-start carry for a ``from_store`` engine (no host experiment
        to mirror): fresh globals, empty queues, ``BoundState``-style tracker
        initials."""
        M = len(self.mods)
        f32 = lambda x: jnp.asarray(x, jnp.float32)     # noqa: E731
        return FusedCarry(
            params=jax.tree.map(jnp.asarray, self._global_params0),
            policy={k: jnp.asarray(v)
                    for k, v in self.policy.init_state().items()},
            Q=f32(np.zeros(self.K)), spent=f32(np.zeros(self.K)),
            zeta=f32(np.full(M, self._init_zeta)),
            delta=f32(np.full((M, self.K), self._init_delta)),
            model_dist=f32(np.zeros(self.K)))

    def round_params(self, carry: FusedCarry):
        """Round-boundary params export for live serving: the carry's global
        fusion params, straight off the device chain — no host mirror write
        (cf. ``export_carry``), so a serving process can hot-swap them into
        its donated buffer tree (``launch/continuous.py``) without waiting
        on the queue/tracker decode."""
        return carry.params

    def export_carry(self, carry: FusedCarry) -> None:
        """Write the carry back into the host-side mirrors (checkpointing,
        final_metrics, interop with the non-fused paths)."""
        exp = self.exp
        exp.global_params = carry.params
        exp.queues.Q = np.asarray(carry.Q, np.float64)
        exp.queues.spent = np.asarray(carry.spent, np.float64)
        exp.queues.t = exp._round
        for i, m in enumerate(self.mods):
            exp.bound.zeta[m] = float(carry.zeta[i])
            exp.bound.delta[m] = np.asarray(carry.delta[i], np.float64)
        exp.model_dist = np.asarray(carry.model_dist, np.float64)
        exp.scheduler.load_state(
            {k: np.asarray(v) for k, v in carry.policy.items()})

    # ------------------------------------------------------------------
    # the fused program
    # ------------------------------------------------------------------
    def _round_step(self, carry: FusedCarry, xs: RoundXs, store,
                    overrides=None, test_set=None,
                    axis_name: Optional[str] = None):
        """One round.  ``store`` is the (possibly shard-local)
        ``ClientStore``; ``axis_name`` names the mesh axis the store and the
        per-client xs leaves are sharded over (None = single device /
        replicated).  Cohort compute is replicated across the client axis —
        only the O(K·N·d) store and the O(R·K) randomness shard.

        ``overrides`` replaces solver-template entries for this round (a
        vmapped V — or, for scenario grids, any per-scenario context:
        gamma/tau_rem/tau_cmp/e_cmp/has/D/wbar...); ``test_set`` is an
        optional ``(features, labels)`` pair replacing the engine's static
        held-out split, so scenario grids evaluate each scenario on its own
        test data."""
        self.trace_count += 1

        # 0. under a client-sharded mesh the *vector* physics stays dense +
        # replicated: reassemble the full channel draw from the shards
        h = xs.h if axis_name is None else \
            lax.all_gather(xs.h, axis_name, tiled=True)

        # 1. server decision: the scheduler's traced policy core (JCSBA's
        # population-batched solve, or a baseline's traced schedule) — the
        # policy state (warm start / cursor / ...) threads through the carry
        data = dict(self._solver_tmpl)
        if overrides:
            data.update(overrides)      # e.g. a vmapped V for scenario sweeps
        data["Q"], data["h"] = carry.Q, h
        data["zeta2"] = jnp.square(carry.zeta)
        data["delta2"] = jnp.square(carry.delta)
        if axis_name is not None and hasattr(self.policy, "hp"):
            # the KKT B_min bisection is the solver's only per-client
            # *compute* (30 fixed iterations × K): run it shard-locally on
            # this shard's slice and all_gather — elementwise, so bit-exact
            K_loc = xs.h.shape[0]
            off = lax.axis_index(axis_name) * K_loc
            sl = lambda x: lax.dynamic_slice_in_dim(x, off, K_loc)  # noqa: E731
            bl, okl = _bmin(sl(data["gamma"]), xs.h, sl(data["tau_rem"]),
                            data["B_max"], data["p_tx"], data["N0"],
                            self.policy.hp)
            data["bmin"] = lax.all_gather(bl, axis_name, tiled=True)
            data["bmin_ok"] = lax.all_gather(okl, axis_name, tiled=True)
        pstate, a, B, J, drop_rows, idx = self.policy.step_full(
            carry.policy, data, carry.model_dist,
            jax.random.PRNGKey(xs.draw_seed))

        # 2. latency feasibility (C4): scheduled-but-late ⇒ failure — energy
        # is spent, nothing is uploaded
        r = rate(jnp.maximum(B, B_LO), h, self._p_tx, self._N0)
        tcom = jnp.where(a, data["gamma"] / jnp.maximum(r, 1e-30), 0.0)
        ok = a & (tcom + data["tau_cmp"] <= self._tau_max + 1e-12)

        # 3. cohort gather + masked BGD updates (Eq. 7) on the [J] stack.
        # The policy's index vector lists scheduled clients first (ascending)
        # with unscheduled padding; ``ok_c`` masks failures and padding alike,
        # so a padding slot contributes exact zeros everywhere downstream.
        if axis_name is None:
            cohort = store.take(idx)
            seeds_c = jnp.take(xs.client_seeds, idx)
        else:
            cohort = jax.tree.map(
                lambda x: _gather_rows(x, idx, axis_name), store)
            seeds_c = _gather_rows(xs.client_seeds, idx, axis_name)
        Jc = idx.shape[0]
        ok_c = jnp.take(ok, idx)
        drop = {m: drop_rows[i] for m, i in self._drop_rows.items()
                if m in self.mods}       # empty for policies without dropout
        drop_c = {m: jnp.take(d, idx) for m, d in drop.items()}
        upload_c = agg.upload_masks_traced(ok_c, cohort.has_modality, drop_c)
        avail_c = {m: upload_c[m].astype(jnp.float32) for m in self.mods}

        def run_cohort(args):
            params, avail, seeds = args
            newp, grads, _totals, dist_sq = self._cohort(
                params, self._init_params, cohort.features, cohort.labels,
                cohort.sample_mask, avail, seeds)
            return newp, grads, dist_sq

        def skip_cohort(args):
            params, _avail, _seeds = args
            newp = jax.tree.map(
                lambda p: jnp.broadcast_to(p, (Jc,) + p.shape), params)
            return (newp, jax.tree.map(jnp.zeros_like, newp),
                    {m: jnp.zeros(Jc, jnp.float32) for m in self.mods})

        newp_c, grads_c, dist_sq_c = lax.cond(
            ok.any(), run_cohort, skip_cohort,
            (carry.params, avail_c, seeds_c))

        # 4. Eq. 12 aggregation on the cohort stack + ζ/δ tracker refresh.
        # Every contributor is in the cohort by construction, so the weight
        # renormalisation over J equals the dense one over K; the dense [K]
        # weight rows the aux records keep are the segment-sum scatter.
        # The trackers consume the per-modality gradient Gram matrix
        # G = Σ_leaves X Xᵀ [J, J]: ζ² = wᵀGw and δ_j² = G_jj − 2(Gw)_j +
        # wᵀGw, so the refresh needs no aggregated-gradient pytree and no
        # second O(J·|θ|) reduction pass over the gradient stack.
        w_c = agg.stacked_weights_traced(cohort.sizes, upload_c)
        new_params = agg.aggregate_stacked_traced(carry.params, newp_c, w_c)
        w = agg.cohort_weights_dense(w_c, idx, self.K)
        zs, ds = [], []
        for i, m in enumerate(self.mods):
            z_m, d_m = tracker_update_gram(
                carry.zeta[i], carry.delta[i], grad_gram(grads_c[m]),
                w_c[m], upload_c[m], idx, data["has"][i], self.staleness)
            zs.append(z_m)
            ds.append(d_m)

        # 5. Lyapunov queue recursion (§V-A) + energy accounting
        used = a.astype(jnp.float32) * (self._p_tx * tcom + data["e_cmp"])
        Qn = queue_update(carry.Q, used, self._E_add)
        spent = carry.spent + used

        # 6. ‖θ_k − θ⁰‖ for participants (Selection-scheduler bookkeeping):
        # cohort-local distances scattered back to the dense row
        d_sq_c = sum(dist_sq_c[m] * avail_c[m] for m in self.mods)
        dist_k = agg.scatter_cohort_rows(
            jnp.where(ok_c, jnp.sqrt(d_sq_c), 0.0), idx, self.K)
        model_dist = jnp.where(ok, dist_k, carry.model_dist)

        # 7. device-resident eval of the fresh globals on the held-out split
        # (the host loop's adapter.evaluate, fused behind the cadence flag —
        # only the branch that actually runs costs anything at runtime)
        tf, tl = test_set if test_set is not None else \
            (self._test_feats, self._test_labels)
        metrics = lax.cond(
            xs.eval_flag,
            lambda p: eval_metrics(p, tf, tl, logits_fn=self._eval_logits),
            lambda p: nan_metrics(tf),
            new_params)

        new_carry = FusedCarry(new_params, pstate, Qn, spent,
                               jnp.stack(zs), jnp.stack(ds), model_dist)
        aux = RoundAux(a, ok, J, w, spent.sum(), drop, metrics, xs.eval_flag)
        return new_carry, aux

    def _scan_steps(self, carry: FusedCarry, xs: RoundXs, store):
        def body(c, x):
            return self._round_step(c, x, store)
        return lax.scan(body, carry, xs)

    # ------------------------------------------------------------------
    def step(self, carry: FusedCarry, xs: RoundXs):
        return self._jit_step(carry, xs, self._store)

    def scan(self, carry: FusedCarry, xs: RoundXs):
        """R rounds in one program; xs leaves carry a leading [R] axis.
        Compiles once per distinct R (then cached)."""
        return self._jit_scan(carry, xs, self._store)

    def _scan_one_v(self, V, carry: FusedCarry, xs: RoundXs, store,
                    axis_name: Optional[str] = None):
        return self._scan_one_scenario({"V": V}, store, None, carry, xs,
                                       axis_name=axis_name)

    def _scan_one_scenario(self, overrides, store, test_set,
                           carry: FusedCarry, xs: RoundXs,
                           axis_name: Optional[str] = None):
        """One scenario's whole experiment: R rounds under ``lax.scan`` with
        this scenario's solver-data overrides / store / test split.  The unit
        ``scan_scenario_grid`` vmaps and shards."""
        def body(c, x):
            return self._round_step(c, x, store, overrides=overrides,
                                    test_set=test_set, axis_name=axis_name)
        return lax.scan(body, carry, xs)

    def scan_scenario_grid(self, overrides, carry: FusedCarry, xs: RoundXs,
                           stores=None, test_sets=None, mesh="auto"):
        """Whole experiments over an arbitrary *scenario* grid — the
        generalization of ``scan_v_grid`` from a V-line to a zoo.

        ``overrides`` is a dict of stacked solver-data entries, every value
        carrying a leading [S] scenario axis over the per-round shapes
        (``V`` → [S], ``gamma``/``tau_rem``/``tau_cmp``/``e_cmp``/``D`` →
        [S, K], ``has``/``wbar`` → [S, M, K]); each scenario's row replaces
        the engine's solver template for its entire experiment
        (``data/scenarios.py::stack_scenarios`` assembles exactly this dict
        from ``ScenarioSpec``s).  ``stores`` optionally stacks per-scenario
        ``ClientStore``s ([S]-leading leaves — scenarios must share K, N and
        the modality set; None = every scenario reads the engine's resident
        store) and ``test_sets`` an ``(features, labels)`` pair with
        [S]-leading leaves for per-scenario eval.  All scenarios share the
        initial carry and the per-round randomness ``xs`` — the controlled-
        comparison convention ``scan_v_grid`` established.

        Runs as one ``jit(vmap(scan))``; on a multi-device 1-D
        ``("scenario",)`` mesh the scenario axis (grid rows, stores, test
        sets alike) shards over devices via ``shard_map`` — bit-exact vs the
        single-device vmap (tests/test_scenarios.py).  The 2-D
        ``("scenario", "clients")`` population mesh is V-grid-only: a
        client-sharded store cannot also carry a scenario axis — use
        ``scan_v_grid`` there."""
        ovr = to_device(dict(overrides))
        n_S = next(iter(ovr.values())).shape[0]
        for k, v in ovr.items():
            if v.shape[0] != n_S:
                raise ValueError(
                    f"override {k!r} has scenario axis {v.shape[0]}, "
                    f"expected {n_S}")
        store_arg = self._store if stores is None else \
            jax.tree.map(jnp.asarray, stores)
        ts_arg = None if test_sets is None else \
            jax.tree.map(jnp.asarray, test_sets)
        if mesh == "auto":
            mesh = make_sweep_mesh()
        key = ("scenario", None if mesh is None else mesh,
               tuple(sorted(ovr)), stores is None, test_sets is None)
        if mesh is None or mesh.devices.size <= 1:
            fn = self._sharded_vsweep_cache.get(key)
            if fn is None:
                fn = jax.jit(jax.vmap(
                    self._scan_one_scenario,
                    in_axes=(0, None if stores is None else 0,
                             None if test_sets is None else 0, None, None)))
                self._sharded_vsweep_cache[key] = fn
            return fn(ovr, store_arg, ts_arg, carry, xs)
        if "clients" in mesh.axis_names:
            raise ValueError(
                "scan_scenario_grid supports 1-D ('scenario',) meshes only; "
                "the 2-D ('scenario', 'clients') population mesh shards the "
                "client store itself — run V-only grids there via "
                "scan_v_grid")
        n_dev = mesh.devices.size
        ovr = pad_leading_axis(ovr, n_dev)
        sharded = [0]
        if stores is not None:
            store_arg = pad_leading_axis(store_arg, n_dev)
            sharded.append(1)
        if test_sets is not None:
            ts_arg = pad_leading_axis(ts_arg, n_dev)
            sharded.append(2)
        fn = self._sharded_vsweep_cache.get(key)
        if fn is None:
            vm = jax.vmap(
                self._scan_one_scenario,
                in_axes=(0, None if stores is None else 0,
                         None if test_sets is None else 0, None, None))
            fn = jax.jit(scenario_shard_map(vm, mesh, n_args=5,
                                            sharded_args=tuple(sharded)))
            self._sharded_vsweep_cache[key] = fn
        carries, auxs = fn(ovr, store_arg, ts_arg, carry, xs)
        return (slice_leading_axis(carries, n_S),
                slice_leading_axis(auxs, n_S))

    def scan_v_grid(self, V_grid, carry: FusedCarry, xs: RoundXs,
                    mesh="auto"):
        """Whole *experiments* over a drift-penalty grid: every V in
        ``V_grid`` runs the full R-round experiment (same initial carry, same
        channel/dropout randomness — the paper's Fig.-4 controlled V study)
        under one ``jit(vmap(scan))``.  Returns (final carries, auxs) with a
        leading [len(V_grid)] axis.  This is the dense V-frontier workload
        the split pipeline cannot express without n_V × R host round-trips.

        Meshes: ``mesh="auto"`` builds a 1-D ``("scenario",)`` mesh over all
        local devices (``launch.mesh.make_sweep_mesh``), ``mesh=None`` forces
        the single-device vmap, or pass an explicit mesh.  A 1-D mesh shards
        the scenario axis only — pure SPMD fan-out (``scenario_shard_map``).
        A 2-D ``("scenario", "clients")`` mesh
        (``launch.mesh.make_population_mesh``) additionally partitions the
        client store and the per-client randomness over the ``"clients"``
        axis (specs from ``launch.sharding.logical_pspec``): each shard holds
        K/n_clients rows of every O(K·N·d) leaf, the round body gathers
        cohorts via masked psums and keeps cohort compute replicated.  Grids
        that don't divide the scenario axis are padded by repeating the last
        V and sliced back; K must divide the clients axis.  Sharded and
        single-device runs produce the same results
        (tests/test_sharded_sweep.py, tests/test_cohort_gather.py)."""
        V = jnp.asarray(V_grid, jnp.float32)
        if mesh == "auto":
            mesh = make_sweep_mesh()
        if mesh is None or mesh.devices.size <= 1 or \
                "clients" not in mesh.axis_names:
            # V is just the simplest scenario grid — one overridden solver
            # entry, engine store and test split shared by every row
            return self.scan_scenario_grid({"V": V}, carry, xs, mesh=mesh)
        n_V = V.shape[0]
        n_cl = int(mesh.shape["clients"])
        if self.K % n_cl:
            raise ValueError(
                f"K={self.K} must divide the mesh's clients axis "
                f"({n_cl} shards)")
        Vp = pad_leading_axis(V, int(mesh.shape["scenario"]))
        fn = self._sharded_vsweep_cache.get(mesh)
        if fn is None:
            vm = jax.vmap(
                functools.partial(self._scan_one_v, axis_name="clients"),
                in_axes=(0, None, None, None))
            xs_spec = RoundXs(
                h=logical_pspec(("rounds", "clients"), mesh),
                draw_seed=logical_pspec(("rounds",), mesh),
                client_seeds=logical_pspec(("rounds", "clients"), mesh),
                eval_flag=logical_pspec(("rounds",), mesh))
            fn = jax.jit(population_shard_map(
                vm, mesh,
                in_specs=(logical_pspec(("scenario",), mesh), P(),
                          xs_spec, logical_pspec(("clients",), mesh)),
                out_specs=logical_pspec(("scenario",), mesh)))
            self._sharded_vsweep_cache[mesh] = fn
        carries, auxs = fn(Vp, carry, xs, self._store)
        return (slice_leading_axis(carries, n_V),
                slice_leading_axis(auxs, n_V))

    # ------------------------------------------------------------------
    def run(self, carry: FusedCarry, xs: RoundXs, scanned: bool):
        """Execute and time; returns (carry, aux-on-host, wall seconds)."""
        t0 = time.perf_counter()
        if scanned:
            carry, aux = self.scan(carry, xs)
        else:
            carry, aux = self.step(carry, xs)
        aux = jax.tree.map(np.asarray, jax.block_until_ready(aux))
        return carry, aux, time.perf_counter() - t0
