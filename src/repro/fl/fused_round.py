"""Fully on-device MFL rounds — schedule → local updates → Eq. 12
aggregation → queue/tracker update as ONE jitted program per round.

PR 1 batched the client fan-out (fl/client.py) and PR 2 batched the server
decision layer (wireless/solver/), but the runtime still hopped to host
between them every round: solver jit → host decode → client jit → host
aggregation → host trackers.  This module chains all four stages inside a
single ``round_step(carry, xs) -> (carry, aux)`` whose carry packs the entire
evolving experiment state, so ``lax.scan`` can drive whole experiments (and,
vmapped, dense V/τ scenario grids — benchmarks/fused_round.py) without
leaving the device.

Carry layout (``FusedCarry``, a pytree):

* ``params``      — the global multimodal model {modality: subtree};
* ``policy``      — the scheduling policy's own state dict
  (``wireless.policies``: JCSBA's warm-start antibody, Round-Robin's cursor,
  empty for Random/Selection) — the engine is policy-generic: any scheduler
  exposing a traced ``SchedulePolicy`` core runs fused;
* ``Q`` / ``spent`` — Lyapunov virtual energy queues + cumulative energy;
* ``zeta`` / ``delta`` — the Theorem-1 ζ_m / δ_{k,m} trackers as dense
  [M] / [M, K] arrays (modality order = ``BoundState.mods``);
* ``model_dist``  — ‖θ_k − θ⁰‖ bookkeeping (read by the Selection policy).

Per-round inputs (``RoundXs``) are the only randomness the loop consumes:
channel gains, the immune-search PRNG seed and per-client dropout seeds —
plus the (deterministic) ``eval_flag`` marking rounds on the ``eval_every``
grid.  They are pregenerated on host by ``draw_round_xs`` in exactly the
order the host loop consumes its ``np.random.Generator`` stream (channel
draws → solver seed → K client seeds — see
``MFLExperiment._draw_client_seeds``), which is what makes the fused path
draw-for-draw equivalent to the host reference: with identical experiment
seeds, participant sets match exactly and params / queues / trackers match
to float32 reduction-order tolerance (tests/test_fused_round.py locks this
contract).

Two per-round decision surfaces ride along since PR 5:

* **modality dropout** — policies whose ``step_full`` emits a drop mask
  ([28]'s baseline, ``wireless.policies.DropoutPolicy``) thread it into the
  Eq. 12 upload masks (``core.aggregation.upload_masks_traced``), so the
  last host-only scheduler now scans on device and the full Table-3
  five-policy comparison is one fused program;
* **device-resident eval** — rounds flagged by ``xs.eval_flag`` evaluate the
  freshly aggregated globals on the held-out split inside the scan
  (``fl.eval.eval_metrics`` behind ``lax.cond``; skipped rounds emit NaN
  fillers gated by ``RoundAux.eval_mask``), so ``run_scanned`` and
  ``scan_v_grid`` produce multimodal + unimodal accuracy *curves* with zero
  host eval calls.

Equivalence caveats (all covered by the tests' tolerances): the host loop
keeps queues/trackers in float64 numpy between the f32 jitted stages, while
the fused carry stays f32 end-to-end — per-round drift is ~1e-7 relative and
does not move the solver's argmin on the tested configs.
"""
from __future__ import annotations

import time
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core import aggregation as agg
from ..core.convergence import tracker_update_masked
from .eval import device_test_set, eval_metrics, nan_metrics
from ..launch.mesh import make_sweep_mesh
from ..launch.sharding import (pad_leading_axis, scenario_shard_map,
                               slice_leading_axis)
from ..wireless.lyapunov import queue_update
from ..wireless.solver import build_solver_data
from ..wireless.solver.common import B_LO
from ..wireless.solver.jaxsolver import rate, to_device


class FusedCarry(NamedTuple):
    """Whole-experiment state threaded through ``lax.scan``."""
    params: Dict[str, Any]
    policy: Dict[str, jax.Array]    # SchedulePolicy state (may be empty)
    Q: jax.Array                # [K]
    spent: jax.Array            # [K]
    zeta: jax.Array             # [M]
    delta: jax.Array            # [M, K]
    model_dist: jax.Array       # [K]


class RoundXs(NamedTuple):
    """Pregenerated per-round randomness (stack leading axis to scan)."""
    h: jax.Array                # [K] channel gains (float32)
    draw_seed: jax.Array        # scalar uint32 — immune-search key seed
    client_seeds: jax.Array     # [K] uint32 — per-client dropout seeds
    eval_flag: jax.Array        # scalar bool — evaluate this round's globals


class RoundAux(NamedTuple):
    """Per-round outputs — the traced stand-in for ScheduleDecision +
    RoundRecord, decoded on host by ``MFLExperiment._decode_fused_round``."""
    a: jax.Array                # [K] bool — scheduled (incl. failures)
    ok: jax.Array               # [K] bool — participated
    J: jax.Array                # scalar solver objective J₂(a*)
    weights: Dict[str, jax.Array]   # Eq. 12 weights w^t_{k,m}
    energy_total: jax.Array     # scalar Σ_k cumulative energy after round
    drop: Dict[str, jax.Array]  # {m: [K] bool} — modality dropped this round
    metrics: Dict[str, jax.Array]   # test metrics (NaN when not evaluated)
    eval_mask: jax.Array        # scalar bool — ``metrics`` is real


def draw_round_xs(exp, rounds: int, eval_every: Optional[int] = None,
                  include_final: bool = False) -> RoundXs:
    """Consume ``rounds`` rounds of the experiment's host randomness in the
    canonical order — one host-loop round exactly: K channel draws
    (``Channel.draw``), one policy seed (the single ``rng.integers(2 ** 31)``
    every policy-backed scheduler draws per round, whatever the policy), then
    the per-client dropout seeds via the experiment's own
    ``_draw_client_seeds`` so that contract stays single-sourced.  A fused
    experiment and a host-loop experiment sharing the same seed therefore
    walk the identical ``np.random`` stream.

    ``eval_flag`` is deterministic, not random: round t is flagged exactly
    when the host loop would evaluate it (``(exp._round + t) % eval_every ==
    0``; ``eval_every`` defaults to the experiment's).  ``include_final``
    additionally flags the last round — sweep drivers use it so every
    scenario's curve ends with the final model's metrics whatever the
    cadence."""
    K = exp.params.K
    ee = int(exp.eval_every if eval_every is None else eval_every)
    h = np.empty((rounds, K), np.float32)
    draw = np.empty(rounds, np.uint32)
    cseed = np.empty((rounds, K), np.uint32)
    flags = np.zeros(rounds, bool)
    for t in range(rounds):
        h[t] = exp.channel.draw()
        draw[t] = exp.rng.integers(2 ** 31)
        cseed[t] = exp._draw_client_seeds()
        flags[t] = (exp._round + t) % ee == 0
    if include_final and rounds:
        flags[-1] = True
    return RoundXs(jnp.asarray(h), jnp.asarray(draw), jnp.asarray(cseed),
                   jnp.asarray(flags))


class FusedRoundEngine:
    """Per-experiment compiler/runner for the fused round program.

    Built lazily by ``MFLExperiment`` (fused=True).  Holds the static,
    device-resident context — padded cohort stack, per-client costs, solver
    template, tracker constants, the held-out test split for the in-scan
    eval — and exposes:

    * ``step(carry, xs)``  — one jitted round;
    * ``scan(carry, xs)``  — R rounds under one ``lax.scan`` (xs stacked);
    * ``init_carry()`` / ``export_carry()`` — host-state ↔ carry conversion.

    ``trace_count`` increments each time the round body is *traced* — the
    zero-host-round-trips contract is asserted as "many rounds, one trace"
    in tests/test_fused_round.py.
    """

    def __init__(self, exp):
        exp.scheduler.bind(exp.params.K, exp.client_mods)
        self.policy = exp.scheduler.policy
        if self.policy is None:
            raise ValueError(
                f"fused rounds require a traced scheduling policy "
                f"(wireless.policies); scheduler {exp.scheduler.name!r} "
                f"runs host-side only")
        self.exp = exp
        self.K = exp.params.K
        self.mods = list(exp.bound.mods)
        self.V = getattr(exp.scheduler, "V", 1.0)
        self.staleness = float(exp.bound.staleness)
        self.trace_count = 0

        # solver-data template: static entries live on device once; Q/h and
        # the ζ²/δ² snapshot are overwritten from the carry every round
        tmpl = build_solver_data(np.zeros(self.K), np.zeros(self.K),
                                 exp.cost, exp.params, exp.bound, self.V)
        self._solver_tmpl = to_device(tmpl)
        self._has = self._solver_tmpl["has"]            # [M, K] bool
        self._D = self._solver_tmpl["D"]                # [K] f32
        self._tau_cmp = jnp.asarray(exp.cost.tau_cmp, jnp.float32)
        self._e_cmp = jnp.asarray(exp.cost.e_cmp, jnp.float32)
        p = exp.params
        self._tau_max = float(p.tau_max)
        self._E_add = float(p.E_add)
        self._p_tx = float(p.p_tx)
        self._N0 = float(p.N0)

        feats, labels, smask = exp._get_stacked()
        self._feats = {m: feats[m] for m in self.mods}
        self._labels, self._smask = labels, smask
        self._init_params = jax.tree.map(jnp.asarray, exp.init_params)
        self._cohort = exp.adapter.cohort_step(tuple(self.mods))

        # device-resident eval context: the held-out split lives on device
        # for the engine's lifetime; rounds flagged by xs.eval_flag run the
        # shared fl.eval.eval_metrics program on the fresh globals
        self._test_feats, self._test_labels = device_test_set(exp.test_ds)

        # drop-mask row -> engine modality index, for policies with dropout
        # (step_full's mask rows follow policy.drop_mods; empty otherwise)
        self._drop_rows = {m: i for i, m in
                           enumerate(getattr(self.policy, "drop_mods", ()))}

        self._jit_step = jax.jit(self._round_step)
        self._jit_scan = jax.jit(self._scan_steps)
        self._jit_vsweep = jax.jit(jax.vmap(self._scan_one_v,
                                            in_axes=(0, None, None)))
        self._sharded_vsweep_cache = {}     # mesh -> jitted shard_map sweep

    # ------------------------------------------------------------------
    # host state ↔ carry
    # ------------------------------------------------------------------
    def init_carry(self) -> FusedCarry:
        exp = self.exp
        f32 = lambda x: jnp.asarray(x, jnp.float32)     # noqa: E731
        return FusedCarry(
            params=jax.tree.map(jnp.asarray, exp.global_params),
            policy={k: jnp.asarray(v)
                    for k, v in exp.scheduler.state().items()},
            Q=f32(exp.queues.Q), spent=f32(exp.queues.spent),
            zeta=f32([exp.bound.zeta[m] for m in self.mods]),
            delta=f32(np.stack([exp.bound.delta[m] for m in self.mods])),
            model_dist=f32(exp.model_dist))

    def export_carry(self, carry: FusedCarry) -> None:
        """Write the carry back into the host-side mirrors (checkpointing,
        final_metrics, interop with the non-fused paths)."""
        exp = self.exp
        exp.global_params = carry.params
        exp.queues.Q = np.asarray(carry.Q, np.float64)
        exp.queues.spent = np.asarray(carry.spent, np.float64)
        exp.queues.t = exp._round
        for i, m in enumerate(self.mods):
            exp.bound.zeta[m] = float(carry.zeta[i])
            exp.bound.delta[m] = np.asarray(carry.delta[i], np.float64)
        exp.model_dist = np.asarray(carry.model_dist, np.float64)
        exp.scheduler.load_state(
            {k: np.asarray(v) for k, v in carry.policy.items()})

    # ------------------------------------------------------------------
    # the fused program
    # ------------------------------------------------------------------
    def _round_step(self, carry: FusedCarry, xs: RoundXs, overrides=None):
        self.trace_count += 1

        # 1. server decision: the scheduler's traced policy core (JCSBA's
        # population-batched solve, or a baseline's traced schedule) — the
        # policy state (warm start / cursor / ...) threads through the carry
        data = dict(self._solver_tmpl)
        if overrides:
            data.update(overrides)      # e.g. a vmapped V for scenario sweeps
        data["Q"], data["h"] = carry.Q, xs.h
        data["zeta2"] = jnp.square(carry.zeta)
        data["delta2"] = jnp.square(carry.delta)
        pstate, a, B, J, drop_rows = self.policy.step_full(
            carry.policy, data, carry.model_dist,
            jax.random.PRNGKey(xs.draw_seed))

        # 2. latency feasibility (C4): scheduled-but-late ⇒ failure — energy
        # is spent, nothing is uploaded
        r = rate(jnp.maximum(B, B_LO), xs.h, self._p_tx, self._N0)
        tcom = jnp.where(a, data["gamma"] / jnp.maximum(r, 1e-30), 0.0)
        ok = a & (tcom + self._tau_cmp <= self._tau_max + 1e-12)

        # 3. masked whole-cohort BGD updates (Eq. 7) — the upload mask is
        # participation ∧ ownership ∧ ¬dropped (the drop mask is all-False
        # except under the dropout baseline, whose step_full emits per-round
        # per-modality drop bits).  An empty round skips the BGD entirely
        # (lax.cond), mirroring the host loop's early return: with every
        # client masked the cohort's outputs are exactly the broadcast
        # globals + zero gradients anyway, so the skip branch is
        # bit-identical and costs only the solver.
        drop = {m: drop_rows[i] for m, i in self._drop_rows.items()
                if m in self.mods}       # empty for policies without dropout
        upload = agg.upload_masks_traced(
            ok, {m: self._has[i] for i, m in enumerate(self.mods)}, drop)
        avail = {m: upload[m].astype(jnp.float32) for m in self.mods}

        def run_cohort(args):
            params, avail, seeds = args
            newp, grads, _totals, dist_sq = self._cohort(
                params, self._init_params, self._feats, self._labels,
                self._smask, avail, seeds)
            return newp, grads, dist_sq

        def skip_cohort(args):
            params, _avail, _seeds = args
            newp = jax.tree.map(
                lambda p: jnp.broadcast_to(p, (self.K,) + p.shape), params)
            return (newp, jax.tree.map(jnp.zeros_like, newp),
                    {m: jnp.zeros(self.K, jnp.float32) for m in self.mods})

        newp, grads, dist_sq = lax.cond(
            ok.any(), run_cohort, skip_cohort,
            (carry.params, avail, xs.client_seeds))

        # 4. Eq. 12 aggregation + ζ/δ tracker refresh
        w = agg.stacked_weights_traced(self._D, upload)
        new_params = agg.aggregate_stacked_traced(carry.params, newp, w)
        agg_grads = agg.aggregate_gradients_stacked_traced(grads, w)
        zs, ds = [], []
        for i, m in enumerate(self.mods):
            z_m, d_m = tracker_update_masked(
                carry.zeta[i], carry.delta[i], grads[m], agg_grads[m],
                upload[m], self._has[i], self.staleness)
            zs.append(z_m)
            ds.append(d_m)

        # 5. Lyapunov queue recursion (§V-A) + energy accounting
        used = a.astype(jnp.float32) * (self._p_tx * tcom + self._e_cmp)
        Qn = queue_update(carry.Q, used, self._E_add)
        spent = carry.spent + used

        # 6. ‖θ_k − θ⁰‖ for participants (Selection-scheduler bookkeeping)
        d_sq = sum(dist_sq[m] * avail[m] for m in self.mods)
        model_dist = jnp.where(ok, jnp.sqrt(d_sq), carry.model_dist)

        # 7. device-resident eval of the fresh globals on the held-out split
        # (the host loop's adapter.evaluate, fused behind the cadence flag —
        # only the branch that actually runs costs anything at runtime)
        metrics = lax.cond(
            xs.eval_flag,
            lambda p: eval_metrics(p, self._test_feats, self._test_labels),
            lambda p: nan_metrics(self._test_feats),
            new_params)

        new_carry = FusedCarry(new_params, pstate, Qn, spent,
                               jnp.stack(zs), jnp.stack(ds), model_dist)
        aux = RoundAux(a, ok, J, w, spent.sum(), drop, metrics, xs.eval_flag)
        return new_carry, aux

    def _scan_steps(self, carry: FusedCarry, xs: RoundXs):
        return lax.scan(self._round_step, carry, xs)

    # ------------------------------------------------------------------
    def step(self, carry: FusedCarry, xs: RoundXs):
        return self._jit_step(carry, xs)

    def scan(self, carry: FusedCarry, xs: RoundXs):
        """R rounds in one program; xs leaves carry a leading [R] axis.
        Compiles once per distinct R (then cached)."""
        return self._jit_scan(carry, xs)

    def _scan_one_v(self, V, carry: FusedCarry, xs: RoundXs):
        def body(c, x):
            return self._round_step(c, x, overrides={"V": V})
        return lax.scan(body, carry, xs)

    def scan_v_grid(self, V_grid, carry: FusedCarry, xs: RoundXs,
                    mesh="auto"):
        """Whole *experiments* over a drift-penalty grid: every V in
        ``V_grid`` runs the full R-round experiment (same initial carry, same
        channel/dropout randomness — the paper's Fig.-4 controlled V study)
        under one ``jit(vmap(scan))``.  Returns (final carries, auxs) with a
        leading [len(V_grid)] axis.  This is the dense V-frontier workload
        the split pipeline cannot express without n_V × R host round-trips.

        The scenario axis is sharded across a device mesh when one is
        available: ``mesh="auto"`` builds a 1-D ``("scenario",)`` mesh over
        all local devices (``launch.mesh.make_sweep_mesh``; virtual CPU
        devices included), ``mesh=None`` forces the single-device vmap, or
        pass an explicit mesh.  Scenarios are independent, so sharding is
        pure SPMD fan-out via ``shard_map`` (``launch.sharding``) — grids
        that don't divide the device count are padded by repeating the last
        V and sliced back.  Sharded and single-device runs produce the same
        results (tests/test_sharded_sweep.py)."""
        V = jnp.asarray(V_grid, jnp.float32)
        if mesh == "auto":
            mesh = make_sweep_mesh()
        if mesh is None or mesh.devices.size <= 1:
            return self._jit_vsweep(V, carry, xs)
        n_V = V.shape[0]
        Vp = pad_leading_axis(V, mesh.devices.size)
        fn = self._sharded_vsweep_cache.get(mesh)
        if fn is None:
            vm = jax.vmap(self._scan_one_v, in_axes=(0, None, None))
            fn = jax.jit(scenario_shard_map(vm, mesh, n_args=3,
                                            sharded_args=(0,)))
            self._sharded_vsweep_cache[mesh] = fn
        carries, auxs = fn(Vp, carry, xs)
        return (slice_leading_axis(carries, n_V),
                slice_leading_axis(auxs, n_V))

    # ------------------------------------------------------------------
    def run(self, carry: FusedCarry, xs: RoundXs, scanned: bool):
        """Execute and time; returns (carry, aux-on-host, wall seconds)."""
        t0 = time.perf_counter()
        if scanned:
            carry, aux = self.scan(carry, xs)
        else:
            carry, aux = self.step(carry, xs)
        aux = jax.tree.map(np.asarray, jax.block_until_ready(aux))
        return carry, aux, time.perf_counter() - t0
