"""Device-resident test-set evaluation — the Table-3 metrics as a pure
traced program.

Every headline number of the paper (Table 3, Figs. 4-6) is a held-out-split
metric: multimodal accuracy (Eq. 1 fused logits), per-modality unimodal
accuracy, and the fused cross-entropy.  Historically those lived only in
``PaperModelAdapter.evaluate`` — a host entry point — so the fused round
engine had to hop to host for every curve point, and the V-frontier paid
n_V ``adapter.evaluate`` round-trips per policy.

``eval_metrics`` is the single source of that computation: a pure function
of ``(params, feats, labels)`` built on the same ``models.paper_models.
modal_logits`` forward pass the training step uses.  It is consumed three
ways, all executing the identical ops:

* ``PaperModelAdapter.evaluate`` jits it standalone (the host API);
* ``FusedRoundEngine`` inlines it into the scanned round program behind a
  per-round ``lax.cond`` flag (``RoundXs.eval_flag``), so experiments emit
  accuracy *curves* at the ``eval_every`` cadence without leaving device;
* ``eval_metrics_stacked`` vmaps it over a leading params axis — one call
  evaluates a whole scenario grid's final models (the V-frontier's shape).

Cross-path agreement (device-resident vs ``adapter.evaluate`` on the same
params) is locked by tests/test_eval_fused.py.
"""
from __future__ import annotations

from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from ..core import fusion
from ..models import paper_models as pm

#: metric keys shared by every evaluation surface, before the per-modality
#: accuracy entries
BASE_METRICS = ("multimodal", "loss")


def metric_keys(mods) -> Tuple[str, ...]:
    """Canonical key order of an ``eval_metrics`` result dict."""
    return BASE_METRICS + tuple(sorted(mods))


def eval_metrics(params: Mapping[str, dict], feats: Mapping[str, jax.Array],
                 labels: jax.Array, *, logits_fn=None) -> Dict[str, jax.Array]:
    """Test-split metrics as f32 scalars: Eq. 1 fused accuracy (key
    ``multimodal``), fused cross-entropy (``loss``) and one unimodal
    accuracy per modality present in ``feats``.  Pure and traced-safe — the
    fused round engine inlines it; the host adapter jits it.

    ``logits_fn(params, feats) -> {modality: [B, C]}`` selects the model
    family (``ModelAdapter.eval_logits``); the default is the paper's
    LSTM/CNN forward, keeping existing callers byte-identical."""
    if logits_fn is None:
        logits_fn = pm.modal_logits
    logits = logits_fn({m: params[m] for m in feats}, dict(feats))
    fused = fusion.fuse_logits(logits)
    out = {"multimodal": fusion.accuracy(fused, labels),
           "loss": fusion.softmax_xent(fused, labels)}
    for m in feats:
        out[m] = fusion.accuracy(logits[m], labels)
    return out


def nan_metrics(mods) -> Dict[str, jax.Array]:
    """The skip-branch twin of ``eval_metrics``: same pytree structure and
    dtypes, every value NaN — what ``lax.cond`` emits on rounds the eval
    cadence skips (consumers gate on ``RoundAux.eval_mask``, never on the
    filler values)."""
    return {k: jnp.float32(jnp.nan) for k in metric_keys(mods)}


def device_test_set(test_ds) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Push a dataset's features/labels to device once (the fused engine
    holds them for the experiment's lifetime)."""
    feats = {m: jnp.asarray(x) for m, x in sorted(test_ds.features.items())}
    return feats, jnp.asarray(test_ds.labels)


def eval_metrics_stacked(stacked_params, feats, labels, *, logits_fn=None):
    """``eval_metrics`` vmapped over a leading scenario axis of ``params`` —
    evaluates e.g. every V-grid row's final model in one device call."""
    return jax.vmap(lambda p: eval_metrics(p, feats, labels,
                                           logits_fn=logits_fn))(stacked_params)
