"""FL client-side computation: the local update of Algorithm 1, lines 4-6.

One epoch of batch gradient descent (BGD) on the local dataset per round, per
§II-A.  The loss is H_k = F_k + G_k (Eq. 4) computed by ``core.fusion``; only
the client's available modalities are updated (missing submodels are neither
computed nor uploaded — Eq. 7 and the discussion below it).

``PaperModelAdapter`` binds this to the paper's LSTM/CNN submodels; the same
interface drives the pods-as-clients mode for LM-scale models
(examples/federated_pods.py).
"""
from __future__ import annotations

import functools
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fusion
from ..core.trees import tree_sq_dist
from ..data.partition import ClientData
from ..kernels.fusion_loss import ops as fusion_kops
from ..models import paper_models as pm
from .eval import eval_metrics

_eval_jit = jax.jit(eval_metrics)


class PaperModelAdapter:
    """Decision-fusion multimodal model made of the paper's submodels."""

    # Default pre-set modal weights v_m (Eq. 3).  The LSTM submodels need a
    # stronger unimodal-loss pull than the CNN to converge under the shared
    # BGD step size η — this is exactly the role the paper assigns v_m
    # ("a pre-set modal weight"); calibration in EXPERIMENTS.md §Repro.
    DEFAULT_V = {"audio": 6.0, "text": 4.0, "image": 1.0}

    def __init__(self, dataset_name: str, eta: float = 0.05,
                 v_weights: Optional[Mapping[str, float]] = None,
                 dropout: float = 0.1, loss_backend: str = "xla"):
        if loss_backend not in ("xla", "pallas"):
            raise ValueError(
                f"unknown loss_backend {loss_backend!r}; expected "
                f"'xla' (core.fusion) or 'pallas' (kernels/fusion_loss "
                f"custom-VJP one-pass loss)")
        self.dataset_name = dataset_name
        self.eta = eta
        self.v_weights = dict(self.DEFAULT_V if v_weights is None
                              else v_weights)
        self.dropout = dropout
        self.loss_backend = loss_backend

    def _loss_fn(self, v_weights):
        """The H_k = F + Σ v_m·G_m computation, backend-selected: the plain
        XLA ``core.fusion.multimodal_loss`` or the one-pass Pallas kernel
        with its custom-VJP backward (``kernels.fusion_loss.ops``) —
        identical semantics, locked by tests/test_fusion_vjp.py."""
        if self.loss_backend == "pallas":
            def loss(logits, labels, avail=None, sample_mask=None):
                return fusion_kops.fused_multimodal_loss(
                    logits, labels, v_weights, avail=avail,
                    sample_mask=sample_mask)
        else:
            def loss(logits, labels, avail=None, sample_mask=None):
                return fusion.multimodal_loss(
                    logits, labels, v_weights, avail=avail,
                    sample_mask=sample_mask)
        return loss

    # ------------------------------------------------------------------
    def init_global(self, key) -> Dict[str, dict]:
        if self.dataset_name == "crema_d":
            return pm.init_crema_model(key)
        if self.dataset_name == "iemocap":
            return pm.init_iemocap_model(key)
        raise ValueError(self.dataset_name)

    # ------------------------------------------------------------------
    @functools.lru_cache(maxsize=32)
    def _update_fn(self, mods: Tuple[str, ...]):
        v_weights = {m: self.v_weights.get(m, 1.0) for m in mods}
        loss_impl = self._loss_fn(v_weights)

        @jax.jit
        def step(params, feats, labels, rng):
            def loss(p):
                logits = pm.modal_logits(p, feats, dropout_rng=rng)
                total, met = loss_impl(logits, labels)
                return total, met["F"]

            (total, F), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new = jax.tree.map(lambda p, g: p - self.eta * g, params, grads)
            return new, grads, total, F

        return step

    def local_update(self, global_params: Mapping[str, dict],
                     client: ClientData, rng: jax.Array,
                     dropout_modality: Optional[str] = None):
        """Returns (updated_subset, grads_subset, loss). Only modalities the
        client trains appear in the outputs."""
        mods = tuple(m for m in client.modalities if m != dropout_modality)
        if not mods:
            mods = client.modalities
        params = {m: global_params[m] for m in mods}
        feats = {m: jnp.asarray(client.dataset.features[m]) for m in mods}
        labels = jnp.asarray(client.dataset.labels)
        new, grads, total, _ = self._update_fn(mods)(params, feats, labels, rng)
        return new, grads, float(total)

    # ------------------------------------------------------------------
    # batched round engine: all clients' local updates in one jitted vmap
    # ------------------------------------------------------------------
    @functools.lru_cache(maxsize=8)
    def cohort_step(self, mods: Tuple[str, ...]):
        """Pure (un-jitted) whole-cohort BGD step over the padded stack.

        The host batched path jits it directly (``_batched_update_fn``); the
        fused round engine (fl/fused_round.py) inlines it into the single
        per-round program, so both execute the identical computation."""
        v_weights = {m: self.v_weights.get(m, 1.0) for m in mods}
        eta = self.eta
        loss_impl = self._loss_fn(v_weights)

        def step(params, init_params, feats, labels, smask, avail, seeds):
            def one(feats_k, labels_k, smask_k, avail_k, seed_k):
                rng = jax.random.key(seed_k)

                def loss(p):
                    logits = pm.modal_logits(p, feats_k, dropout_rng=rng)
                    total, met = loss_impl(logits, labels_k, avail=avail_k,
                                           sample_mask=smask_k)
                    return total, met["F"]

                (total, _), grads = jax.value_and_grad(
                    loss, has_aux=True)(params)
                new = jax.tree.map(lambda p, g: p - eta * g, params, grads)
                dist_sq = {m: tree_sq_dist(new[m], init_params[m])
                           for m in mods}
                return new, grads, total, dist_sq

            ax0 = {m: 0 for m in mods}
            return jax.vmap(one, in_axes=(ax0, 0, 0, ax0, 0))(
                feats, labels, smask, avail, seeds)

        return step

    @functools.lru_cache(maxsize=8)
    def _batched_update_fn(self, mods: Tuple[str, ...]):
        return jax.jit(self.cohort_step(mods))

    def batched_local_update(self, global_params: Mapping[str, dict],
                             init_params: Mapping[str, dict],
                             feats: Mapping[str, jax.Array],
                             labels: jax.Array, sample_mask: jax.Array,
                             avail: Mapping[str, np.ndarray],
                             seeds: np.ndarray):
        """One BGD epoch for the *whole cohort* as a single jitted vmap.

        ``feats[m]`` is a padded [K, N, ...] stack (data.partition), ``avail``
        a per-modality 0/1 upload mask [K] and ``seeds`` the per-client
        dropout seeds (0 for unscheduled clients).  A masked-out modality
        contributes exactly zero to the loss, so its gradient is exactly
        zero and the "new" params equal the broadcast globals — downstream
        aggregation masks them out again, reproducing the sequential
        skip-the-dict-key semantics.

        Returns stacked pytrees (leading client axis K): new params, grads,
        per-client total loss, and per-modality squared distance to
        ``init_params`` (for the Selection scheduler's model_dist).
        """
        mods = tuple(sorted(feats.keys()))
        avail_f = {m: jnp.asarray(np.asarray(avail[m], np.float32))
                   for m in mods}
        seeds_j = jnp.asarray(np.asarray(seeds, np.uint32))
        return self._batched_update_fn(mods)(
            {m: global_params[m] for m in mods},
            {m: init_params[m] for m in mods},
            {m: feats[m] for m in mods},
            labels, sample_mask, avail_f, seeds_j)

    # ------------------------------------------------------------------
    def evaluate(self, params: Mapping[str, dict], test) -> Dict[str, float]:
        # the one test-metric computation, shared with the fused round
        # engine's device-resident eval (fl/eval.py single-sources it);
        # jit specialisation per modality set / shapes is jax's own cache
        mods = tuple(sorted(test.features.keys()))
        feats = {m: jnp.asarray(test.features[m]) for m in mods}
        labels = jnp.asarray(test.labels)
        out = _eval_jit({m: params[m] for m in mods}, feats, labels)
        return {k: float(v) for k, v in out.items()}

    def __hash__(self):   # lru_cache on methods needs a hashable self
        return hash((self.dataset_name, self.eta, self.dropout,
                     self.loss_backend,
                     tuple(sorted(self.v_weights.items()))))

    def __eq__(self, other):
        return self is other
