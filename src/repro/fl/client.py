"""FL client-side computation: the local update of Algorithm 1, lines 4-6.

One epoch of batch gradient descent (BGD) on the local dataset per round, per
§II-A.  The loss is H_k = F_k + G_k (Eq. 4) computed by ``core.fusion``; only
the client's available modalities are updated (missing submodels are neither
computed nor uploaded — Eq. 7 and the discussion below it).

The paper's analysis (Theorem 1, Eq. 12) is architecture-agnostic, and so is
this module: ``ModelAdapter`` owns every piece of the local update that does
*not* depend on the architecture (the single-client and whole-cohort BGD
steps, the loss-backend selection, optional per-client remat, eval), while
subclasses supply only ``init_global`` and ``modal_logits``:

* ``PaperModelAdapter`` — the paper's faithful LSTM/CNN submodels
  (models/paper_models.py);
* ``BackboneAdapter`` — transformer- or SSD-backed unimodal encoders built
  from the LM-scale blocks (models/multimodal.py::encoder_apply over
  ``ENCODER_PRESETS``), optionally routing the mixers through the
  flash_attention / ssd_scan Pallas kernels (``use_kernels=True``).

``make_adapter`` maps the scenario grid's architecture axis
(``ScenarioSpec.arch`` ∈ ``models.config.FL_ARCHS``) to the right class.
"""
from __future__ import annotations

import functools
from typing import Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import fusion
from ..core.trees import tree_sq_dist
from ..data.partition import ClientData
from ..data.scenarios import DATASET_SHAPES
from ..kernels.fusion_loss import ops as fusion_kops
from ..models import multimodal as mm
from ..models import paper_models as pm
from ..models.config import FL_ARCHS, encoder_config
from .eval import eval_metrics


class ModelAdapter:
    """Architecture-agnostic local-update machinery (Algorithm 1, ll. 4-6).

    Subclasses define the model family via ``init_global`` (global param
    pytree) and ``modal_logits`` (per-modality decision logits); everything
    else — BGD step, cohort vmap, loss backend, eval — is shared.  Instances
    are *value objects*: ``__eq__``/``__hash__`` derive from ``_key()`` so
    equal-valued adapters are interchangeable and share the ``lru_cache``-d
    compiled steps (all behavior is a pure function of the key).
    """

    #: default pre-set modal weights v_m (Eq. 3); subclasses override
    DEFAULT_V: Dict[str, float] = {"audio": 1.0, "text": 1.0, "image": 1.0}

    def __init__(self, dataset_name: str, eta: float = 0.05,
                 v_weights: Optional[Mapping[str, float]] = None,
                 dropout: float = 0.1, loss_backend: str = "xla",
                 remat: bool = False):
        if loss_backend not in ("xla", "pallas"):
            raise ValueError(
                f"unknown loss_backend {loss_backend!r}; expected "
                f"'xla' (core.fusion) or 'pallas' (kernels/fusion_loss "
                f"custom-VJP one-pass loss)")
        self.dataset_name = dataset_name
        self.eta = eta
        self.v_weights = dict(self.DEFAULT_V if v_weights is None
                              else v_weights)
        self.dropout = dropout
        self.loss_backend = loss_backend
        self.remat = remat

    # ------------------------------------------------------------------
    # value semantics (hash/eq contract: equal keys <=> equal behavior)
    # ------------------------------------------------------------------
    def _key(self) -> tuple:
        return (type(self).__name__, self.dataset_name, self.eta,
                self.dropout, self.loss_backend, self.remat,
                tuple(sorted(self.v_weights.items())))

    def __hash__(self):   # lru_cache on methods needs a hashable self
        return hash(self._key())

    def __eq__(self, other):
        if not isinstance(other, ModelAdapter):
            return NotImplemented
        return self._key() == other._key()

    # ------------------------------------------------------------------
    # the architecture: subclasses implement these two
    # ------------------------------------------------------------------
    def init_global(self, key) -> Dict[str, dict]:
        """Global model: {modality: param pytree}."""
        raise NotImplementedError

    def modal_logits(self, params, inputs: dict, *, dropout_rng=None):
        """Per-modality [B, C] logits for the modalities in ``inputs``."""
        raise NotImplementedError

    def eval_logits(self, params, inputs: dict):
        """Deterministic (no-dropout) logits for test-set evaluation."""
        return self.modal_logits(params, inputs)

    # ------------------------------------------------------------------
    def _loss_fn(self, v_weights):
        """The H_k = F + Σ v_m·G_m computation, backend-selected: the plain
        XLA ``core.fusion.multimodal_loss`` or the one-pass Pallas kernel
        with its custom-VJP backward (``kernels.fusion_loss.ops``) —
        identical semantics, locked by tests/test_fusion_vjp.py."""
        if self.loss_backend == "pallas":
            def loss(logits, labels, avail=None, sample_mask=None):
                return fusion_kops.fused_multimodal_loss(
                    logits, labels, v_weights, avail=avail,
                    sample_mask=sample_mask)
        else:
            def loss(logits, labels, avail=None, sample_mask=None):
                return fusion.multimodal_loss(
                    logits, labels, v_weights, avail=avail,
                    sample_mask=sample_mask)
        return loss

    # ------------------------------------------------------------------
    @functools.lru_cache(maxsize=32)
    def _update_fn(self, mods: Tuple[str, ...]):
        v_weights = {m: self.v_weights.get(m, 1.0) for m in mods}
        loss_impl = self._loss_fn(v_weights)

        @jax.jit
        def step(params, feats, labels, rng):
            def loss(p):
                logits = self.modal_logits(p, feats, dropout_rng=rng)
                total, met = loss_impl(logits, labels)
                return total, met["F"]

            if self.remat:
                loss = jax.checkpoint(loss)
            (total, F), grads = jax.value_and_grad(loss, has_aux=True)(params)
            new = jax.tree.map(lambda p, g: p - self.eta * g, params, grads)
            return new, grads, total, F

        return step

    def local_update(self, global_params: Mapping[str, dict],
                     client: ClientData, rng: jax.Array,
                     dropout_modality: Optional[str] = None):
        """Returns (updated_subset, grads_subset, loss). Only modalities the
        client trains appear in the outputs."""
        mods = tuple(m for m in client.modalities if m != dropout_modality)
        if not mods:
            mods = client.modalities
        params = {m: global_params[m] for m in mods}
        feats = {m: jnp.asarray(client.dataset.features[m]) for m in mods}
        labels = jnp.asarray(client.dataset.labels)
        new, grads, total, _ = self._update_fn(mods)(params, feats, labels, rng)
        return new, grads, float(total)

    # ------------------------------------------------------------------
    # batched round engine: all clients' local updates in one jitted vmap
    # ------------------------------------------------------------------
    @functools.lru_cache(maxsize=8)
    def cohort_step(self, mods: Tuple[str, ...]):
        """Pure (un-jitted) whole-cohort BGD step over the padded stack.

        The host batched path jits it directly (``_batched_update_fn``); the
        fused round engine (fl/fused_round.py) inlines it into the single
        per-round program, so both execute the identical computation.  With
        ``remat=True`` each client's loss is ``jax.checkpoint``-wrapped, so
        the vmapped backward recomputes per-client forward activations
        instead of holding [K, ...] stacks of them live — the memory lever
        for the large-backbone adapters (BENCH_backbone_rounds.json)."""
        v_weights = {m: self.v_weights.get(m, 1.0) for m in mods}
        eta = self.eta
        loss_impl = self._loss_fn(v_weights)

        def step(params, init_params, feats, labels, smask, avail, seeds):
            def one(feats_k, labels_k, smask_k, avail_k, seed_k):
                rng = jax.random.key(seed_k)

                def loss(p):
                    logits = self.modal_logits(p, feats_k, dropout_rng=rng)
                    total, met = loss_impl(logits, labels_k, avail=avail_k,
                                           sample_mask=smask_k)
                    return total, met["F"]

                if self.remat:
                    loss = jax.checkpoint(loss)
                (total, _), grads = jax.value_and_grad(
                    loss, has_aux=True)(params)
                new = jax.tree.map(lambda p, g: p - eta * g, params, grads)
                dist_sq = {m: tree_sq_dist(new[m], init_params[m])
                           for m in mods}
                return new, grads, total, dist_sq

            ax0 = {m: 0 for m in mods}
            return jax.vmap(one, in_axes=(ax0, 0, 0, ax0, 0))(
                feats, labels, smask, avail, seeds)

        return step

    @functools.lru_cache(maxsize=8)
    def _batched_update_fn(self, mods: Tuple[str, ...]):
        return jax.jit(self.cohort_step(mods))

    def batched_local_update(self, global_params: Mapping[str, dict],
                             init_params: Mapping[str, dict],
                             feats: Mapping[str, jax.Array],
                             labels: jax.Array, sample_mask: jax.Array,
                             avail: Mapping[str, np.ndarray],
                             seeds: np.ndarray):
        """One BGD epoch for the *whole cohort* as a single jitted vmap.

        ``feats[m]`` is a padded [K, N, ...] stack (data.partition), ``avail``
        a per-modality 0/1 upload mask [K] and ``seeds`` the per-client
        dropout seeds (0 for unscheduled clients).  A masked-out modality
        contributes exactly zero to the loss, so its gradient is exactly
        zero and the "new" params equal the broadcast globals — downstream
        aggregation masks them out again, reproducing the sequential
        skip-the-dict-key semantics.

        Returns stacked pytrees (leading client axis K): new params, grads,
        per-client total loss, and per-modality squared distance to
        ``init_params`` (for the Selection scheduler's model_dist).
        """
        mods = tuple(sorted(feats.keys()))
        avail_f = {m: jnp.asarray(np.asarray(avail[m], np.float32))
                   for m in mods}
        seeds_j = jnp.asarray(np.asarray(seeds, np.uint32))
        return self._batched_update_fn(mods)(
            {m: global_params[m] for m in mods},
            {m: init_params[m] for m in mods},
            {m: feats[m] for m in mods},
            labels, sample_mask, avail_f, seeds_j)

    # ------------------------------------------------------------------
    @functools.lru_cache(maxsize=2)
    def _eval_fn(self):
        # the one test-metric computation, shared with the fused round
        # engine's device-resident eval (fl/eval.py single-sources it);
        # jit specialisation per modality set / shapes is jax's own cache
        return jax.jit(functools.partial(eval_metrics,
                                         logits_fn=self.eval_logits))

    def evaluate(self, params: Mapping[str, dict], test) -> Dict[str, float]:
        mods = tuple(sorted(test.features.keys()))
        feats = {m: jnp.asarray(test.features[m]) for m in mods}
        labels = jnp.asarray(test.labels)
        out = self._eval_fn()({m: params[m] for m in mods}, feats, labels)
        return {k: float(v) for k, v in out.items()}


class PaperModelAdapter(ModelAdapter):
    """Decision-fusion multimodal model made of the paper's submodels."""

    # Default pre-set modal weights v_m (Eq. 3).  The LSTM submodels need a
    # stronger unimodal-loss pull than the CNN to converge under the shared
    # BGD step size η — this is exactly the role the paper assigns v_m
    # ("a pre-set modal weight"); calibration in EXPERIMENTS.md §Repro.
    DEFAULT_V = {"audio": 6.0, "text": 4.0, "image": 1.0}

    def init_global(self, key) -> Dict[str, dict]:
        if self.dataset_name == "crema_d":
            return pm.init_crema_model(key)
        if self.dataset_name == "iemocap":
            return pm.init_iemocap_model(key)
        raise ValueError(self.dataset_name)

    def modal_logits(self, params, inputs: dict, *, dropout_rng=None):
        return pm.modal_logits(params, inputs, dropout_rng=dropout_rng,
                               dropout=self.dropout)


class BackboneAdapter(ModelAdapter):
    """Transformer- or SSD-backed unimodal encoders under decision fusion.

    Each modality's feature stack runs through a small sequence encoder
    built from the LM-scale blocks (``models.config.ENCODER_PRESETS``) to
    C-class logits; fusion/loss/aggregation are the shared machinery — the
    scenario grid's architecture axis.  ``use_kernels=True`` routes the
    mixers through the flash_attention / ssd_scan Pallas kernels (custom
    VJPs recompute the backward via the XLA reference path, so the kernels
    sit on the *training* hot path under the cohort vmap).
    """

    DEFAULT_V = {"audio": 1.0, "text": 1.0, "image": 1.0}

    def __init__(self, dataset_name: str, arch: str = "transformer",
                 use_kernels: bool = False, **kw):
        super().__init__(dataset_name, **kw)
        self.arch = arch
        self.use_kernels = use_kernels
        self.cfg = encoder_config(arch)

    def _key(self) -> tuple:
        return super()._key() + (self.arch, self.use_kernels)

    @property
    def _impl(self) -> str:
        return "pallas" if self.use_kernels else "xla"

    def init_global(self, key) -> Dict[str, dict]:
        shapes, n_classes = DATASET_SHAPES[self.dataset_name]
        mods = tuple(sorted(shapes))
        keys = jax.random.split(key, len(mods))
        return {m: mm.init_encoder(
                    k, int(np.prod(shapes[m][1:], dtype=np.int64)),
                    n_classes, self.cfg)
                for m, k in zip(mods, keys)}

    def modal_logits(self, params, inputs: dict, *, dropout_rng=None):
        out = {}
        for m in sorted(inputs):
            rng = None
            if dropout_rng is not None:
                # same global per-modality constants as the paper models, so
                # a modality-subset call and the full masked stack draw
                # identical masks (pm.MODALITY_INDEX rationale)
                rng = jax.random.fold_in(dropout_rng, pm.MODALITY_INDEX[m])
            out[m] = mm.encoder_apply(
                params[m], inputs[m], self.cfg, dropout_rng=rng,
                dropout=self.dropout, remat=self.remat, impl=self._impl)
        return out


def make_adapter(dataset_name: str, arch: str = "lstm-cnn",
                 use_kernels: bool = False, **kw) -> ModelAdapter:
    """Adapter for one point of the architecture axis (``FL_ARCHS``)."""
    if arch == "lstm-cnn":
        return PaperModelAdapter(dataset_name, **kw)
    if arch not in FL_ARCHS:
        raise ValueError(f"unknown arch {arch!r}; choose from {FL_ARCHS}")
    return BackboneAdapter(dataset_name, arch=arch, use_kernels=use_kernels,
                           **kw)
