"""The wireless MFL loop — Algorithm 1 of the paper.

Per communication round t:
  1. redraw channel gains h_k;
  2. the server solves the scheduling/bandwidth problem (JCSBA or a baseline).
     JCSBA runs on the population-batched solver (``wireless.solver``) — one
     fused jitted program per round evaluating the whole immune population;
     the engine spec's backend suffix (``engine="batched:np"`` /
     ``"seq:seq"``) selects its float64 numpy mirror or the original
     sequential scalar path (see ``schedulers.JCSBAScheduler``);
  3. scheduled clients run the local update (one BGD epoch, Eq. 7) — clients
     whose latency constraint is violated under the chosen bandwidth are
     *transmission failures*: they consume energy but contribute no update
     (this is what punishes the naive equal-bandwidth baselines);
  4. per-modality aggregation with participated weights (Eq. 12);
  5. Lyapunov queues and the Theorem-1 ζ/δ trackers are updated;
  6. test metrics (multimodal + per-modality accuracy) are recorded.

Round engines (``engine=`` — "seq" | "batched" | "fused")
---------------------------------------------------------
One kwarg selects how rounds execute; a ``":<backend>"`` suffix picks the
JCSBA solver backend for parity studies (``"batched:np"`` — float64 numpy
mirror, ``"seq:seq"`` — the original scalar path; default jax).

Batched round engine (default, ``engine="batched"``)
----------------------------------------------------
Step 3 historically re-entered JAX once per scheduled client.  The batched
engine instead executes *all* K clients' one-epoch BGD updates as a single
jitted ``jax.vmap`` over a dense, device-resident client stack, making the
round — not the client — the unit of compute:

* **Padding.** At experiment init the cohort is stacked into a
  ``data.partition.StackedClients``: every modality is materialised for every
  client at a fixed ``max_batch`` (the largest shard), ragged shards are
  zero-padded, and a ``sample_mask`` [K, N] marks real samples.  Shapes are
  round-invariant, so the step compiles exactly once.
* **Masking.** A per-modality 0/1 *upload mask* [K] (scheduled ∧ no
  transmission failure ∧ owns the modality ∧ did not drop it) replaces the
  sequential path's skip-the-dict-key convention: a masked-out modality
  contributes exactly zero to the fused loss (core.fusion), hence exactly
  zero gradient, and is excluded from Eq. 12 by the same mask
  (core.aggregation.stacked_weights / aggregate_stacked).  Dropout draws
  per-sample keys (models.paper_models), so padding never perturbs the
  masks of real samples.
* **Equivalence.** With the same seed and schedule, the batched and
  sequential paths produce identical Eq. 12 weights and globally aggregated
  params up to float32 reduction order (tests/test_batched_equivalence.py).
  The sequential loop is kept behind ``engine="seq"`` for exactly this A/B.

Fused round engine (``engine="fused"``)
---------------------------------------
The batched engine still hops to host between the jitted solver and the
jitted client stage every round.  ``engine="fused"`` runs the *whole* round —
steps 1-6 above, test metrics included via the device-resident ``fl.eval``
pass — as one jitted program (fl/fused_round.py) for every scheduler with a
traced policy core (jcsba / random / round_robin / selection / dropout —
see ``wireless.policies``; only the np/seq JCSBA parity backends are
excluded): ``run_round`` becomes a thin host wrapper that pregenerates the
round's randomness, calls the fused step and decodes the traced schedule /
drop-mask / metric arrays into a JSON-safe RoundRecord; ``run_scanned(R)``
drives R rounds under a single ``lax.scan``.  Per-round host rng consumption
is static (see ``_draw_client_seeds``; every policy draws exactly one solver
seed per round), so all engines consume the identical stream and stay
equivalent round by round (tests/test_fused_round.py, parametrized over all
five policies).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional

import jax
import numpy as np

from ..core import aggregation as agg
from ..core.convergence import BoundState
from ..data import synthetic
from ..data.partition import partition, train_test_split
from ..wireless import cost as wcost
from ..wireless.channel import Channel
from ..wireless.lyapunov import EnergyQueues
from ..wireless.params import MODALITY_PROFILES, WirelessParams
from ..wireless.schedulers import (ScheduleContext, Scheduler, make_scheduler)
from .client import make_adapter


def jnp_or_np(x):
    """Record/JSON-boundary normalizer: accepts jnp OR np values (e.g. fields
    produced under jit) and returns plain Python objects — 0-d arrays become
    scalars, 1-d+ arrays become lists, containers recurse.  Every
    ``RoundRecord`` is built through this so device arrays never leak into
    ``json.dump`` of histories or checkpoint manifests (regression test in
    tests/test_fused_round.py)."""
    if isinstance(x, dict):
        return {k: jnp_or_np(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [jnp_or_np(v) for v in x]
    if hasattr(x, "ndim"):
        x = np.asarray(x)
        return x.item() if x.ndim == 0 else x.tolist()
    return x


@dataclasses.dataclass
class RoundRecord:
    round: int
    participants: List[int]
    failures: List[int]
    energy_total: float
    metrics: Dict[str, float]
    sched_time_s: float
    #: modality -> sorted clients that dropped it this round ([28]'s
    #: modality-dropout baseline; empty for every other policy)
    dropped: Dict[str, List[int]] = dataclasses.field(default_factory=dict)

    @classmethod
    def make(cls, round, participants, failures, energy_total, metrics,
             sched_time_s, dropped=None) -> "RoundRecord":
        """The one constructor both round engines use — normalizes every
        field through ``jnp_or_np`` so records are always JSON-safe."""
        return cls(int(jnp_or_np(round)),
                   [int(v) for v in jnp_or_np(list(participants))],
                   [int(v) for v in jnp_or_np(list(failures))],
                   float(jnp_or_np(energy_total)),
                   {k: float(jnp_or_np(v)) for k, v in metrics.items()},
                   float(jnp_or_np(sched_time_s)),
                   {str(m): sorted(int(k) for k in ks)
                    for m, ks in (dropped or {}).items()})


#: valid ``engine=`` loop names, in increasing fusion order
ENGINE_LOOPS = ("seq", "batched", "fused")

#: valid "+"-joined engine-spec backend tokens after the ":" — JCSBA solver
#: parity backends ('np'/'seq'), the Pallas hot path ('pallas': custom-VJP
#: fusion loss, plus kernel-backed mixers for the backbone adapters) and
#: per-client activation checkpointing ('remat')
ENGINE_TOKENS = ("jax", "np", "seq", "pallas", "remat")


def parse_engine(engine: str):
    """``"<loop>[:<token>[+<token>...]]"`` → (loop, solver_backend,
    loss_backend, remat, use_kernels, canonical spec).

    Examples: ``"fused"``, ``"batched:np"``, ``"fused:pallas"``,
    ``"fused:remat"``, ``"fused:pallas+remat"``."""
    loop, _, rest = engine.partition(":")
    if loop not in ENGINE_LOOPS:
        raise ValueError(
            f"unknown engine {engine!r}; expected "
            f"'seq' | 'batched' | 'fused' with an optional "
            f"':<token>[+<token>...]' suffix from {ENGINE_TOKENS} "
            f"(a jcsba solver backend 'np'/'seq', 'pallas' for the "
            f"kernel-backed hot path, 'remat' for per-client "
            f"activation checkpointing)")
    tokens = [t for t in rest.split("+") if t] if rest else []
    for t in tokens:
        if t not in ENGINE_TOKENS:
            raise ValueError(
                f"unknown engine token {t!r} in {engine!r}; "
                f"choose from {ENGINE_TOKENS}")
    solver = [t for t in tokens if t in ("np", "seq")]
    if len(solver) > 1:
        raise ValueError(f"conflicting solver backends in {engine!r}")
    solver_backend = solver[0] if solver else "jax"
    loss_backend = "pallas" if "pallas" in tokens else "xla"
    remat = "remat" in tokens
    canon = loop + (":" + "+".join(tokens) if tokens else ":jax")
    return loop, solver_backend, loss_backend, remat, "pallas" in tokens, canon


class MFLExperiment:
    def __init__(self, dataset: str = "crema_d", scheduler: str = "jcsba",
                 K: int = 10, omega: float = 0.3, n_samples: int = 1200,
                 dirichlet_alpha: float = 0.0,
                 eta: float = 0.05, V: float = 1.0, seed: int = 0,
                 params: Optional[WirelessParams] = None,
                 scheduler_kwargs: Optional[dict] = None,
                 eval_every: int = 1, engine: str = "batched",
                 arch: str = "lstm-cnn"):
        # engine-spec token routing: 'pallas' selects the custom-VJP Pallas
        # fusion-loss on the client BGD hot path (kernels/fusion_loss) —
        # and, for the backbone adapters, the kernel-backed mixers too —
        # leaving the JCSBA solver on its traced 'jax' core; 'np'/'seq'
        # remain the host-side JCSBA parity solvers on the XLA loss; 'remat'
        # activation-checkpoints each client's loss in the cohort step.
        (loop, solver_backend, loss_backend, remat, use_kernels,
         self.engine) = parse_engine(engine)
        self.rng = np.random.default_rng(seed)
        self.params = params or WirelessParams(K=K)
        self.eval_every = eval_every
        self.batched = loop == "batched"
        self.fused = loop == "fused"
        self._fused_engine = None           # built lazily (fl/fused_round.py)
        self._carry = None                  # FusedCarry when fused
        self._stacked_dev = None            # device-resident client stack
        self._stacked_src = None            # cohort it was built from
        self._store_dev = None              # device-resident ClientStore
        self._store_src = None              # cohort it was built from

        full = synthetic.DATASETS[dataset](seed=seed, n=n_samples)
        self.train_ds, self.test_ds = train_test_split(full, 0.2, seed)
        self.clients = partition(self.train_ds, K, omega, seed,
                                 dirichlet_alpha=dirichlet_alpha)
        self.all_mods = sorted(full.features.keys())
        self.client_mods = [c.modalities for c in self.clients]
        self.data_sizes = [c.size for c in self.clients]
        self.profile = MODALITY_PROFILES[dataset]

        # the model-family axis: 'lstm-cnn' (the paper's submodels) or a
        # transformer/SSD encoder stack (fl/client.py::make_adapter)
        self.arch = arch
        self.adapter = make_adapter(dataset, arch, eta=eta,
                                    loss_backend=loss_backend, remat=remat,
                                    use_kernels=use_kernels)
        self.global_params = self.adapter.init_global(jax.random.key(seed))
        self.init_params = jax.tree.map(lambda x: x, self.global_params)

        self.cost = wcost.client_costs(self.data_sizes, self.client_mods,
                                       self.profile, self.params)
        self.channel = Channel(self.params, self.rng)
        self.queues = EnergyQueues(K)
        w_bar = agg.unified_weights(self.data_sizes, self.client_mods,
                                    self.all_mods)
        self.bound = BoundState(K, self.all_mods, self.client_mods, w_bar,
                                self.data_sizes, eta=eta)
        self.w_bar = w_bar
        kw = dict(scheduler_kwargs or {})
        if scheduler == "jcsba":
            kw.setdefault("V", V)
            kw.setdefault("solver", solver_backend)
        self.scheduler: Scheduler = make_scheduler(scheduler, self.rng, **kw)
        self.scheduler.bind(K, self.client_mods)
        if self.fused and self.scheduler.policy is None:
            raise ValueError(
                f"engine='fused' requires a traced scheduling policy; "
                f"scheduler={scheduler!r} with backend={solver_backend!r} runs "
                f"host-side only (every scheduler has a traced core — "
                f"jcsba/random/round_robin/selection/dropout — except "
                f"JCSBA's np/seq parity backends)")
        self.model_dist = np.zeros(K)
        self.history: List[RoundRecord] = []
        self._round = 0

    # ------------------------------------------------------------------
    def run_round(self) -> RoundRecord:
        if self.fused:
            return self._run_round_fused()
        t = self._round
        K = self.params.K
        h = self.channel.draw()
        ctx = ScheduleContext(h=h, Q=self.queues.Q, cost=self.cost,
                              params=self.params, bound=self.bound,
                              round_idx=t, model_dist=self.model_dist,
                              client_modalities=self.client_mods)
        t0 = time.perf_counter()
        dec = self.scheduler.schedule(ctx)
        sched_time = time.perf_counter() - t0

        tcom = wcost.com_latency(dec.B, h, self.cost.gamma_bits, self.params)
        ecom = wcost.com_energy(tcom, self.params)
        ok = dec.a & (tcom + self.cost.tau_cmp <= self.params.tau_max + 1e-12)
        failures = sorted(np.flatnonzero(dec.a & ~ok))
        participants = sorted(np.flatnonzero(ok))

        # --- local updates + aggregation (Eq. 12) + trackers ---
        seeds = self._draw_client_seeds()
        if self.batched:
            w_t = self._round_batched(dec, participants, seeds)
        else:
            w_t = self._round_sequential(dec, participants, seeds)
        self.last_weights = w_t
        self.queues.step(dec.a.astype(float), ecom, self.cost.e_cmp,
                         self.params.E_add)

        metrics = {}
        if t % self.eval_every == 0:
            metrics = self.adapter.evaluate(self.global_params, self.test_ds)
        dropped: Dict[str, List[int]] = {}
        if dec.dropout_modality:
            for k, m in enumerate(dec.dropout_modality):
                if m is not None:
                    dropped.setdefault(m, []).append(k)
        rec = RoundRecord.make(t, participants, failures,
                               self.queues.spent.sum(), metrics, sched_time,
                               dropped)
        self.history.append(rec)
        self._round += 1
        return rec

    # ------------------------------------------------------------------
    # fused engine (fl/fused_round.py): the whole round as one jitted program
    # ------------------------------------------------------------------
    def _get_fused_engine(self):
        if self._fused_engine is None:
            from .fused_round import FusedRoundEngine
            self._fused_engine = FusedRoundEngine(self)
        if self._carry is None:
            self._carry = self._fused_engine.init_carry()
        return self._fused_engine

    def _decode_fused_round(self, t: int, aux, sched_time: float
                            ) -> RoundRecord:
        """Host-side decoder: traced schedule/energy/eval arrays →
        RoundRecord.  Metrics come from the device-resident eval — real only
        on rounds the cadence flagged (``aux.eval_mask``); the NaN fillers of
        skipped rounds never reach a record."""
        a = np.asarray(aux.a, bool)
        ok = np.asarray(aux.ok, bool)
        self.last_weights = {m: np.asarray(aux.weights[m])
                             for m in self.all_mods}
        metrics = {}
        if bool(aux.eval_mask):
            metrics = {k: float(v) for k, v in aux.metrics.items()}
        dropped = {m: np.flatnonzero(np.asarray(d, bool))
                   for m, d in aux.drop.items()}
        return RoundRecord.make(t, sorted(np.flatnonzero(ok)),
                                sorted(np.flatnonzero(a & ~ok)),
                                aux.energy_total, metrics, sched_time,
                                {m: ks for m, ks in dropped.items()
                                 if len(ks)})

    def _run_round_fused(self) -> RoundRecord:
        # note: the record's sched_time_s holds the WHOLE fused-step wall
        # time (the stages are inseparable inside one program; round 0
        # includes jit compilation) — the host path times only the scheduler
        from .fused_round import draw_round_xs
        eng = self._get_fused_engine()
        xs = draw_round_xs(self, 1)
        xs = jax.tree.map(lambda x: x[0], xs)
        self._carry, aux, wall = eng.run(self._carry, xs, scanned=False)
        rec = self._decode_fused_round(self._round, aux, wall)
        self.history.append(rec)
        self._round += 1
        # keep the public host-side mirrors (global_params, queues, bound,
        # model_dist) live — a device->host copy, not a round-trip: the
        # carry stays the compute chain's source of truth
        eng.export_carry(self._carry)
        return rec

    def run_scanned(self, rounds: int) -> List[RoundRecord]:
        """R rounds under a single ``lax.scan`` — one device program for the
        whole stretch.  Per-round randomness is pregenerated in the canonical
        stream order, so the result is identical to R ``run_round()`` calls
        (asserted bit-for-bit in tests/test_system.py).  Test metrics are
        evaluated *inside* the scan on every round of the ``eval_every`` grid
        (the device-resident ``fl.eval`` pass — intermediate global params
        still never materialise on host), so one scan yields the full
        accuracy curve; ``sched_time_s`` records the mean per-round wall time
        of the whole fused scan (compile included on the first call), not the
        host path's scheduler-only time."""
        if not self.fused:
            raise RuntimeError("run_scanned requires engine='fused'")
        from .fused_round import draw_round_xs
        eng = self._get_fused_engine()
        xs = draw_round_xs(self, rounds)
        self._carry, auxs, wall = eng.run(self._carry, xs, scanned=True)
        start, per = self._round, wall / max(rounds, 1)
        recs = []
        for i in range(rounds):
            aux = jax.tree.map(lambda x: x[i], auxs)
            recs.append(self._decode_fused_round(start + i, aux, per))
        self.history.extend(recs)
        self._round += rounds
        eng.export_carry(self._carry)     # host mirrors stay live (see above)
        return recs

    # ------------------------------------------------------------------
    # local-update fan-out: sequential (reference) vs batched (default)
    # ------------------------------------------------------------------
    def _draw_client_seeds(self) -> np.ndarray:
        """One dropout seed per client, every round, scheduled or not.

        The consumption pattern is *static* (K scalar draws per round), so the
        np-rng stream is independent of the schedule outcome — which lets the
        fused round engine (fl/fused_round.py) pregenerate the whole stream
        for a ``lax.scan`` over rounds while staying draw-for-draw identical
        to the host loop.  Client k always uses ``seeds[k]``."""
        return np.array([self.rng.integers(2 ** 31)
                         for _ in range(self.params.K)], np.uint32)

    def _round_sequential(self, dec, participants,
                          seeds: np.ndarray) -> Dict[str, np.ndarray]:
        """Reference path: one JAX re-entry per scheduled client."""
        K = self.params.K
        client_params: List[Optional[dict]] = [None] * K
        client_grads: List[Optional[dict]] = [None] * K
        for k in participants:
            drop = (dec.dropout_modality[k]
                    if dec.dropout_modality is not None else None)
            rng = jax.random.key(int(seeds[k]))
            newp, grads, _ = self.adapter.local_update(
                self.global_params, self.clients[k], rng, drop)
            client_params[k] = newp
            client_grads[k] = grads
            self.model_dist[k] = float(np.sqrt(sum(
                float(np.vdot(a - b, a - b).real)
                for a, b in zip(jax.tree.leaves(newp),
                                jax.tree.leaves({m: self.init_params[m]
                                                 for m in newp})))))

        # participated weights (Eq. 12), renormalised over what was actually
        # uploaded (a dropped modality is absent from the client's upload).
        w_t = agg.weights_from_uploads(self.data_sizes, client_params,
                                       self.all_mods)
        self.global_params = agg.aggregate(self.global_params, client_params,
                                           w_t)
        agg_grads = agg.aggregate_gradients(client_grads, w_t)
        self.bound.update(client_grads, agg_grads)
        return w_t

    def _round_batched(self, dec, participants,
                       seeds: np.ndarray) -> Dict[str, np.ndarray]:
        """Batched path: the whole cohort's updates in one jitted vmap."""
        K = self.params.K
        upload = {m: np.zeros(K, bool) for m in self.all_mods}
        for k in participants:
            drop = (dec.dropout_modality[k]
                    if dec.dropout_modality is not None else None)
            mods = tuple(m for m in self.client_mods[k] if m != drop)
            if not mods:
                mods = tuple(self.client_mods[k])
            for m in mods:
                upload[m][k] = True
        if not len(participants):
            return agg.stacked_weights(self.data_sizes, upload)

        feats, labels, smask = self._get_stacked()
        newp, grads, _totals, dist_sq = self.adapter.batched_local_update(
            self.global_params, self.init_params, feats, labels, smask,
            upload, seeds)

        w_t = agg.stacked_weights(self.data_sizes, upload)
        self.global_params = agg.aggregate_stacked(self.global_params, newp,
                                                   w_t)
        agg_grads = agg.aggregate_gradients_stacked(grads, w_t)
        self.bound.update_stacked(grads, upload, agg_grads)

        d_sq = np.zeros(K)
        for m in self.all_mods:
            d_sq += np.asarray(dist_sq[m]) * upload[m]
        part = np.asarray(participants, int)
        self.model_dist[part] = np.sqrt(d_sq[part])
        return w_t

    def _get_stacked(self):
        """Device-resident padded client stack, rebuilt if the cohort is
        swapped out (e.g. a non-IID repartition after init).  Keyed on the
        identities of the ClientData objects, so replacing the list *or*
        individual entries invalidates the cache; mutating a client's
        dataset arrays in place does not and is unsupported."""
        src = tuple(map(id, self.clients))
        if self._stacked_dev is None or self._stacked_src != src:
            import jax.numpy as jnp
            from ..data.partition import stack_clients
            sc = stack_clients(self.clients, self.all_mods)
            self._stacked_dev = (
                {m: jnp.asarray(x) for m, x in sc.features.items()},
                jnp.asarray(sc.labels), jnp.asarray(sc.sample_mask))
            self._stacked_src = src
        return self._stacked_dev

    def _get_store(self):
        """Device-resident ``ClientStore`` (the fused engine's population
        store; data/partition.py) — same cohort-identity invalidation
        contract as ``_get_stacked``."""
        src = tuple(map(id, self.clients))
        if self._store_dev is None or self._store_src != src:
            import jax.numpy as jnp
            from ..data.partition import build_client_store, stack_clients
            sc = stack_clients(self.clients, self.all_mods)
            store = build_client_store(sc, self.cost.gamma_bits,
                                       self.cost.tau_cmp, self.cost.e_cmp)
            self._store_dev = jax.tree.map(jnp.asarray, store)
            self._store_src = src
        return self._store_dev

    def run(self, rounds: int, verbose: bool = False) -> List[RoundRecord]:
        for _ in range(rounds):
            rec = self.run_round()
            if verbose and rec.metrics:
                acc = rec.metrics.get("multimodal", float("nan"))
                print(f"[{self.scheduler.name}] round {rec.round:4d} "
                      f"acc={acc:.4f} E={rec.energy_total:.3f}J "
                      f"sched={rec.sched_time_s * 1e3:.1f}ms "
                      f"part={rec.participants}")
        return self.history

    # ------------------------------------------------------------------
    # checkpoint / resume (server state: global model + queues + trackers)
    # ------------------------------------------------------------------
    def save(self, path: str) -> str:
        from ..checkpoint import save_checkpoint
        if self.fused and self._carry is not None:
            # the carry is authoritative mid-fused-experiment: mirror it back
            # into the host-side state the checkpoint schema reads
            self._fused_engine.export_carry(self._carry)
        state = {
            "global_params": self.global_params,
            "queues_Q": self.queues.Q,
            "queues_spent": self.queues.spent,
            "delta": {m: self.bound.delta[m] for m in self.all_mods},
            "model_dist": self.model_dist,
            # the policy's own evolving state (JCSBA warm-start antibody,
            # Round-Robin cursor, ...) via the explicit checkpoint API
            "policy": self.scheduler.state(),
        }
        meta = {"round": self._round,
                "zeta": {m: float(self.bound.zeta[m]) for m in self.all_mods},
                "queues_t": self.queues.t}
        return save_checkpoint(path, state, step=self._round, metadata=meta)

    def restore(self, path: str) -> int:
        import jax.numpy as jnp
        from ..checkpoint import load_checkpoint
        state, manifest = load_checkpoint(path)
        self.global_params = jax.tree.map(
            jnp.asarray, state["global_params"])
        self.queues.Q = np.asarray(state["queues_Q"])
        self.queues.spent = np.asarray(state["queues_spent"])
        self.queues.t = manifest["metadata"]["queues_t"]
        for m in self.all_mods:
            self.bound.delta[m] = np.asarray(state["delta"][m])
            self.bound.zeta[m] = manifest["metadata"]["zeta"][m]
        self.model_dist = np.asarray(state["model_dist"])
        # policy state via the explicit API; stateless policies saved nothing
        # (the empty dict flattens away).  Pre-policy checkpoints stored the
        # JCSBA warm start as a top-level "warm_a" blob — still restored, but
        # deprecated: save() has written only the policy/ state dict since
        # the traced-policy layer landed, so re-saving migrates in place.
        pol = state.get("policy")
        if pol is None and "warm_a" in state:
            warnings.warn(
                "checkpoint uses the legacy top-level 'warm_a' warm-start "
                "blob; restored this time — re-save the experiment to "
                "migrate to the policy/ state-dict format (see README "
                "'Checkpoint migration')",
                DeprecationWarning, stacklevel=2)
            pol = {"warm_a": state["warm_a"]}
        if pol:
            self.scheduler.load_state(pol)
        self._round = manifest["step"]
        if self.fused:
            # rebuild the fused carry from the restored host state
            self._carry = None
            self._get_fused_engine()
        return self._round

    # ------------------------------------------------------------------
    def final_metrics(self) -> Dict[str, float]:
        for rec in reversed(self.history):
            if rec.metrics:
                out = dict(rec.metrics)
                out["energy_total"] = self.history[-1].energy_total
                out["mean_sched_time_s"] = float(np.mean(
                    [r.sched_time_s for r in self.history]))
                return out
        return {}
