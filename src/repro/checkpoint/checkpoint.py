"""Pytree checkpointing: npz blob + JSON manifest (no orbax dependency).

Leaves are flattened by '/'-joined key path; the manifest records tree
structure, dtypes and step metadata so restore round-trips exactly.

Two on-disk layouts share the manifest schema:

* **tree** (``save_checkpoint``): one npz entry per leaf — human-greppable.
* **flat** (``save_flat_checkpoint``): one contiguous blob per dtype in the
  ``launch/parambuf`` serving layout (leaf order/offsets recorded under
  ``manifest["flat"]``), so a serving process can mmap-load straight into
  its packed buffer tree.  ``load_checkpoint`` detects the layout from the
  manifest and returns the identical nested dict either way.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _set_path(tree: dict, key: str, value):
    parts = key.split("/")
    cur = tree
    for part in parts[:-1]:
        cur = cur.setdefault(part, {})
    cur[parts[-1]] = value


def save_checkpoint(path: str, tree: Any, step: int = 0,
                    metadata: Optional[dict] = None) -> str:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    fn = os.path.join(path, f"ckpt_{step:08d}")
    np.savez(fn + ".npz", **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "metadata": metadata or {},
    }
    with open(fn + ".json", "w") as f:
        json.dump(manifest, f, indent=1)
    return fn + ".npz"


def save_flat_checkpoint(path: str, tree: Any, step: int = 0,
                         metadata: Optional[dict] = None) -> str:
    """Save through the ``launch/parambuf`` flat layout: one contiguous 1-D
    blob per dtype instead of one npz entry per leaf.  The manifest keeps the
    tree-layout fields (``keys``/``dtypes``/``shapes``) so consumers that
    only read the manifest see no difference; ``load_checkpoint`` restores
    the identical nested dict transparently."""
    from ..launch.parambuf import pack_np, spec_of
    os.makedirs(path, exist_ok=True)
    spec = spec_of(tree)
    bufs, _ = pack_np(tree, spec)
    fn = os.path.join(path, f"ckpt_{step:08d}")
    np.savez(fn + ".npz", **{f"flat__{dt}": b for dt, b in bufs.items()})
    manifest = {
        "step": step,
        "keys": sorted(ls.path for ls in spec.leaves),
        "dtypes": {ls.path: ls.dtype for ls in spec.leaves},
        "shapes": {ls.path: list(ls.shape) for ls in spec.leaves},
        "layout": "flat",
        "flat": {
            "order": [[ls.path, list(ls.shape), ls.dtype, ls.offset]
                      for ls in spec.leaves],
            "buffers": {dt: n for dt, n in spec.sizes},
        },
        "metadata": metadata or {},
    }
    with open(fn + ".json", "w") as f:
        json.dump(manifest, f, indent=1)
    return fn + ".npz"


def load_checkpoint(path: str, step: Optional[int] = None
                    ) -> Tuple[dict, dict]:
    """Returns (tree-as-nested-dicts, manifest). Lists are restored as dicts
    keyed '#i' — callers that saved dict-only pytrees round-trip exactly.
    Flat-layout checkpoints (``save_flat_checkpoint``) are detected from the
    manifest and unpacked to the same nested dict."""
    if step is None:
        fn = latest_checkpoint(path)
        if fn is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    else:
        fn = os.path.join(path, f"ckpt_{step:08d}.npz")
    with open(fn[:-4] + ".json") as f:
        manifest = json.load(f)
    blob = np.load(fn)
    tree: dict = {}
    if manifest.get("layout") == "flat":
        for key, shape, dt, off in manifest["flat"]["order"]:
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            _set_path(tree, key,
                      blob[f"flat__{dt}"][off:off + n].reshape(shape))
        return tree, manifest
    for k in manifest["keys"]:
        _set_path(tree, k, blob[k])
    return tree, manifest


def latest_checkpoint(path: str) -> Optional[str]:
    if not os.path.isdir(path):
        return None
    pat = re.compile(r"ckpt_(\d+)\.npz$")
    best, best_step = None, -1
    for f in os.listdir(path):
        m = pat.match(f)
        if m and int(m.group(1)) > best_step:
            best_step = int(m.group(1))
            best = os.path.join(path, f)
    return best
