from .checkpoint import (save_checkpoint, save_flat_checkpoint,
                         load_checkpoint, latest_checkpoint)

__all__ = ["save_checkpoint", "save_flat_checkpoint", "load_checkpoint",
           "latest_checkpoint"]
