"""Pytree optimizers (no optax dependency).

API: each factory returns an object with
    init(params)            -> state
    update(grads, state, params) -> (updates, state)
Apply with ``apply_updates(params, updates)`` (updates are *added*).

Adafactor implements factored second moments (Shazeer & Stern 2018) — the
memory-sane choice for the ≥52B assigned architectures (see DESIGN.md §5 note
on kimi-k2's optimizer-state footprint).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.vdot(x, x).real for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: x * scale, grads), g


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


@dataclasses.dataclass
class Optimizer:
    init: Callable
    update: Callable


# ---------------------------------------------------------------------------
def sgd(lr):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"]
        upd = jax.tree.map(lambda g: -lr_fn(step) * g.astype(jnp.float32), grads)
        return upd, {"step": step + 1}

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params=None):
        m = jax.tree.map(lambda m_, g: beta * m_ + g.astype(jnp.float32),
                         state["m"], grads)
        upd = jax.tree.map(lambda m_: -lr_fn(state["step"]) * m_, m)
        return upd, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(m_, v_, p):
            u = -(lr_fn(step) * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps))
            if weight_decay:
                u = u - lr_fn(step) * weight_decay * p.astype(jnp.float32)
            return u

        return (jax.tree.map(upd, m, v, params),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)


def adamw(lr, weight_decay: float = 0.01, **kw):
    return adam(lr, weight_decay=weight_decay, **kw)


def adafactor(lr, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8):
    """Factored second-moment optimizer: O(n+m) state for an n x m matrix."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        def zf(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "f": jax.tree.map(zf, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array))}

    def update(grads, state, params=None):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, f):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if g.ndim >= 2:
                r = beta * f["r"] + (1 - beta) * g2.mean(axis=-1)
                c = beta * f["c"] + (1 - beta) * g2.mean(axis=-2)
                vhat = (r[..., None] * c[..., None, :]
                        / jnp.maximum(r.mean(-1, keepdims=True)[..., None], eps))
                newf = {"r": r, "c": c}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                vhat = v
                newf = {"v": v}
            u = g32 * jax.lax.rsqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_fn(step) * u, newf

        flat_g, tdef = jax.tree.flatten(grads)
        flat_f = tdef.flatten_up_to(state["f"])
        outs = [upd(g, f) for g, f in zip(flat_g, flat_f)]
        updates = tdef.unflatten([o[0] for o in outs])
        newfs = tdef.unflatten([o[1] for o in outs])
        return updates, {"step": step, "f": newfs}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def lr(step):
        frac = jnp.clip(step / total_steps, 0.0, 1.0)
        return base_lr * (min_frac + (1 - min_frac) * 0.5 *
                          (1 + jnp.cos(jnp.pi * frac)))
    return lr


def warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                  min_frac: float = 0.05):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), min_frac)

    def lr(step):
        w = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        return jnp.where(step < warmup, base_lr * w, cos(step - warmup))
    return lr


OPTIMIZERS = {"sgd": sgd, "momentum": momentum, "adam": adam,
              "adamw": adamw, "adafactor": adafactor}
