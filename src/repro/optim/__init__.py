from .optimizers import (adafactor, adam, adamw, momentum, sgd,
                         cosine_schedule, warmup_cosine, apply_updates,
                         global_norm, clip_by_global_norm, OPTIMIZERS)

__all__ = ["sgd", "momentum", "adam", "adamw", "adafactor",
           "cosine_schedule", "warmup_cosine", "apply_updates",
           "global_norm", "clip_by_global_norm", "OPTIMIZERS"]
