"""Pallas TPU flash attention (forward): causal / sliding-window, GQA.

Online-softmax accumulation in VMEM scratch; the S x S score matrix is never
materialised in HBM.  Block sizes default to MXU-aligned (128) tiles.

Grid: (B, H, Sq/Tq, Sk/Tk) with the key axis innermost; the KV BlockSpec
index map folds GQA (kv head = q head // (H/K)) so no KV replication happens
in HBM.  Fully-masked tiles are skipped with ``pl.when`` — on TPU this turns
the causal/windowed sweep into the expected ~half/banded work.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float, block_q: int, block_k: int,
            causal: bool, window: Optional[int]):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q0 = iq * block_q
    k0 = ik * block_k
    # tile-level skip decision (static per grid point is impossible — index is
    # dynamic — so use pl.when on a scalar predicate)
    relevant = jnp.bool_(True)
    if causal:
        relevant &= k0 <= q0 + block_q - 1
    if window is not None:
        relevant &= (k0 + block_k - 1) > (q0 - window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)                 # [Tq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                 # [Tk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_s[...] = l_s[...] * alpha + p.sum(axis=-1)
        acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           window: Optional[int] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q: [B, H, S, hd]; k/v: [B, K, S, hd] -> [B, H, S, hd]."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    R = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    grid = (B, H, S // block_q, S // block_k)
    scale = 1.0 / math.sqrt(hd)

    kern = functools.partial(_kernel, scale=scale, block_q=block_q,
                             block_k=block_k, causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // R, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // R, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
