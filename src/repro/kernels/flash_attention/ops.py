"""jit'd wrapper: layout adaptation [B,S,H,hd] <-> [B,H,S,hd] + CPU fallback.

``models.layers.attention_fwd`` can be pointed at this implementation on TPU
(``attention_impl="pallas"`` in the serving/training drivers); the dry-run and
CPU tests use the chunked-jnp path, which this kernel matches bit-for-bit in
fp32 (see tests/test_kernels.py sweeps).
"""
from __future__ import annotations

from typing import Optional

import jax

from .kernel import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None, **kw):
    """q: [B, S, H, hd]; k/v: [B, S, K, hd] (models.layers layout)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                               interpret=interpret, **kw)
    return o.transpose(0, 2, 1, 3)
