"""Pure-jnp oracle for the flash-attention kernel (causal / sliding window,
GQA)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: [B, H, S, hd]; k/v: [B, K, S, hd] -> [B, H, S, hd]."""
    B, H, S, hd = q.shape
    K = k.shape[1]
    R = H // K
    qg = q.reshape(B, K, R, S, hd)
    s = jnp.einsum("bkrqh,bksh->bkrqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkrqs,bksh->bkrqh", p.astype(v.dtype), v)
    return o.reshape(B, H, S, hd).astype(q.dtype)
