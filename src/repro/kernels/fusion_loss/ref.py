"""Pure-jnp oracle for the fused decision-fusion loss kernel, fwd + bwd.

Inputs
  logits: [M, T, V]   stacked per-modality logits (any float dtype)
  labels: [T] int32
  avail:  [M, T] float — 0/1 availability of modality m for token t
Outputs
  fused_nll: [T] f32   — CE of the availability-averaged logits (Eq. 1)
  modal_nll: [M, T] f32 — per-modality CE (Eq. 3), zero where unavailable

The ``*_f64`` twins run the same math in float64 (when jax x64 is enabled —
tests wrap them in ``jax.experimental.enable_x64``) and serve as the gradient
oracle for the custom-VJP Pallas backward: ``fusion_loss_ref_grads`` emits
the logits cotangent and the ζ/δ partials (gsq = ‖dx_m‖², gdot = ⟨dx_m,
g_fused⟩) by materialising the softmax probabilities the kernel never does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _f64_or_f32():
    return jnp.float64 if jax.config.jax_enable_x64 else jnp.float32


def _fusion_loss_impl(logits, labels, avail, dt):
    lg = logits.astype(dt)
    a = avail.astype(dt)
    denom = jnp.maximum(a.sum(0), 1e-9)                    # [T]
    fused = jnp.einsum("mtv,mt->tv", lg, a) / denom[:, None]

    def nll(x, y):
        lse = jax.nn.logsumexp(x, axis=-1)
        gold = jnp.take_along_axis(x, y[..., None], axis=-1)[..., 0]
        return lse - gold

    fused_nll = nll(fused, labels)
    modal_nll = jax.vmap(lambda x: nll(x, labels))(lg) * a
    return fused_nll, modal_nll


def fusion_loss_ref(logits: jax.Array, labels: jax.Array, avail: jax.Array):
    return _fusion_loss_impl(logits, labels, avail, jnp.float32)


def fusion_loss_ref_f64(logits, labels, avail):
    """Float64 forward twin (f32 when x64 is disabled)."""
    return _fusion_loss_impl(logits, labels, avail, _f64_or_f32())


def fusion_loss_ref_grads(logits, labels, avail, d_fused, d_modal):
    """Backward oracle: (dlogits [M, T, V], gsq [M], gdot [M]).

    ``d_fused`` [T] / ``d_modal`` [M, T] are the cotangents of
    (fused_nll, modal_nll).  Runs in float64 when x64 is enabled.  The
    partials are defined on the token grid: for a broadcast head the kernel
    path reduces the [T, V] gradient to the compact operand *after* these
    sums, so the oracle matches the kernel's accumulators exactly."""
    dt = _f64_or_f32()
    lg = logits.astype(dt)
    a = avail.astype(dt)
    df = d_fused.astype(dt)
    dm = d_modal.astype(dt)
    M, T, V = lg.shape
    denom = jnp.maximum(a.sum(0), 1e-9)                    # [T]
    fused = jnp.einsum("mtv,mt->tv", lg, a) / denom[:, None]
    p_f = jax.nn.softmax(fused, axis=-1)                   # [T, V]
    p_m = jax.nn.softmax(lg, axis=-1)                      # [M, T, V]
    onehot = jax.nn.one_hot(labels, V, dtype=dt)           # [T, V]
    base = df[:, None] * (p_f - onehot)                    # [T, V]
    d = ((a / denom)[..., None] * base[None]
         + (dm * a)[..., None] * (p_m - onehot[None]))     # [M, T, V]
    gsq = (d * d).sum((1, 2))
    gdot = (d * base[None]).sum((1, 2))
    return d, gsq, gdot
