"""Pure-jnp oracle for the fused decision-fusion loss kernel.

Inputs
  logits: [M, T, V]   stacked per-modality logits (any float dtype)
  labels: [T] int32
  avail:  [M, T] float — 0/1 availability of modality m for token t
Outputs
  fused_nll: [T] f32   — CE of the availability-averaged logits (Eq. 1)
  modal_nll: [M, T] f32 — per-modality CE (Eq. 3), zero where unavailable
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fusion_loss_ref(logits: jax.Array, labels: jax.Array, avail: jax.Array):
    M, T, V = logits.shape
    lg = logits.astype(jnp.float32)
    a = avail.astype(jnp.float32)
    denom = jnp.maximum(a.sum(0), 1e-9)                    # [T]
    fused = jnp.einsum("mtv,mt->tv", lg, a) / denom[:, None]

    def nll(x, y):
        lse = jax.nn.logsumexp(x, axis=-1)
        gold = jnp.take_along_axis(x, y[..., None], axis=-1)[..., 0]
        return lse - gold

    fused_nll = nll(fused, labels)
    modal_nll = jax.vmap(lambda x: nll(x, labels))(lg) * a
    return fused_nll, modal_nll
