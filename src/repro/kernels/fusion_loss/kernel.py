"""Pallas TPU kernel: fused decision-level-fusion + softmax-CE.

The paper's claim (§II) is that adding the unimodal losses is computationally
free because the unimodal logits already exist.  At LM scale the *loss itself*
becomes the bottleneck: materialising M softmaxes over a 151k-262k vocab is
HBM-bound.  This kernel tiles the vocab axis into VMEM blocks and computes the
fused log-sum-exp and all M per-modality CEs in ONE pass over the logits —
each logit element is read exactly once from HBM.

Grid: (T/Tb, V/Vb), vocab innermost; online (streaming) logsumexp state lives
in VMEM scratch across vocab tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(labels_ref, logits_ref, avail_ref,
            fused_nll_ref, modal_nll_ref,
            mf, sf, gf, mm, sm, gm, *, n_mod: int, block_v: int):
    iv = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(iv == 0)
    def _init():
        mf[...] = jnp.full_like(mf, NEG_INF)
        sf[...] = jnp.zeros_like(sf)
        gf[...] = jnp.zeros_like(gf)
        mm[...] = jnp.full_like(mm, NEG_INF)
        sm[...] = jnp.zeros_like(sm)
        gm[...] = jnp.zeros_like(gm)

    logits = logits_ref[...].astype(jnp.float32)           # [M, Tb, Vb]
    avail = avail_ref[...].astype(jnp.float32)             # [M, Tb]
    labels = labels_ref[...]                               # [Tb]

    denom = jnp.maximum(avail.sum(0), 1e-9)                # [Tb]
    fused = (jnp.einsum("mtv,mt->tv", logits, avail)
             / denom[:, None])                             # [Tb, Vb]

    # --- streaming logsumexp: fused ---
    tile_max = fused.max(axis=-1)                          # [Tb]
    m_new = jnp.maximum(mf[...], tile_max)
    sf[...] = (sf[...] * jnp.exp(mf[...] - m_new)
               + jnp.exp(fused - m_new[:, None]).sum(-1))
    mf[...] = m_new

    # --- streaming logsumexp: per modality ---
    t_max = logits.max(axis=-1)                            # [M, Tb]
    mm_new = jnp.maximum(mm[...], t_max)
    sm[...] = (sm[...] * jnp.exp(mm[...] - mm_new)
               + jnp.exp(logits - mm_new[..., None]).sum(-1))
    mm[...] = mm_new

    # --- gold logit extraction (label may fall in this vocab tile) ---
    v0 = iv * block_v
    idx = labels - v0                                      # [Tb]
    in_tile = (idx >= 0) & (idx < block_v)
    safe = jnp.clip(idx, 0, block_v - 1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (labels.shape[0], block_v), 1)
              == safe[:, None])
    pick = jnp.where(in_tile[:, None], onehot, False)
    gf[...] = gf[...] + jnp.where(pick, fused, 0.0).sum(-1)
    gm[...] = gm[...] + jnp.where(pick[None], logits, 0.0).sum(-1)

    @pl.when(iv == nv - 1)
    def _finalize():
        fused_nll_ref[...] = (mf[...] + jnp.log(sf[...]) - gf[...]
                              ).astype(fused_nll_ref.dtype)
        nll = mm[...] + jnp.log(sm[...]) - gm[...]
        modal_nll_ref[...] = (nll * avail).astype(modal_nll_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fusion_loss_pallas(logits: jax.Array, labels: jax.Array,
                       avail: jax.Array, *, block_t: int = 128,
                       block_v: int = 2048, interpret: bool = False):
    """logits [M,T,V], labels [T] int32, avail [M,T] -> (fused_nll [T],
    modal_nll [M,T]), both f32."""
    M, T, V = logits.shape
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    assert T % block_t == 0 and V % block_v == 0, (T, V, block_t, block_v)
    grid = (T // block_t, V // block_v)

    kern = functools.partial(_kernel, n_mod=M, block_v=block_v)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t,), lambda it, iv: (it,)),
            pl.BlockSpec((M, block_t, block_v), lambda it, iv: (0, it, iv)),
            pl.BlockSpec((M, block_t), lambda it, iv: (0, it)),
        ],
        out_specs=[
            pl.BlockSpec((block_t,), lambda it, iv: (it,)),
            pl.BlockSpec((M, block_t), lambda it, iv: (0, it)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((M, T), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),       # mf
            pltpu.VMEM((block_t,), jnp.float32),       # sf
            pltpu.VMEM((block_t,), jnp.float32),       # gf
            pltpu.VMEM((M, block_t), jnp.float32),     # mm
            pltpu.VMEM((M, block_t), jnp.float32),     # sm
            pltpu.VMEM((M, block_t), jnp.float32),     # gm
        ],
        interpret=interpret,
    )(labels, logits, avail)
