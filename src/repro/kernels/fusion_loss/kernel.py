"""Pallas TPU kernels: fused decision-level-fusion + softmax-CE, fwd + bwd.

The paper's claim (§II) is that adding the unimodal losses is computationally
free because the unimodal logits already exist.  At LM scale the *loss itself*
becomes the bottleneck: materialising M softmaxes over a 151k-262k vocab is
HBM-bound.  The forward kernel tiles the vocab axis into VMEM blocks and
computes the fused log-sum-exp and all M per-modality CEs in ONE pass over the
logits — each logit element is read exactly once from HBM.  With
``save_residuals=True`` it additionally emits the online-softmax residuals
(per-row max and log-sum-exp for the fused mixture and every unimodal head),
which is everything the backward needs besides the logits themselves.

The backward kernel (``fusion_loss_bwd_pallas``) re-reads the logits once and
emits ``dlogits`` per modality in a single blocked pass — softmax
probabilities exist only tile-at-a-time in VMEM, never materialised:

    d x[m,t,v] = gf[t]·(avail[m,t]/denom[t])·(p_f[t,v] − 1{v=y_t})
               + gm[m,t]·avail[m,t]·(p_m[m,t,v] − 1{v=y_t})

where p_f/p_m are reconstructed from the saved residuals.  ``avail``
multiplies every term, so masked modalities and padded rows get *exact-zero*
gradients.  As free by-products the backward accumulates, across all tiles,
the per-modality squared norm ‖dx_m‖² and the dot ⟨dx_m, g_fused⟩ of the
logits gradient (``gsq``/``gdot`` — the Theorem-1 ζ/δ partials in logits
space; see core.convergence for the param-space twin).

Grid: (T/Tb, V/Vb), vocab innermost; streaming state lives in VMEM scratch
across vocab tiles.  Per-modality logits arrive as separate refs (variadic),
so callers never materialise an [M, T, V] stack in HBM; a broadcast head
(e.g. vision [B, 1, V] against labels [B, S]) is fed as its compact [B, V]
array with a tile→batch-row index map (``seg[m] = S``, requires Tb | S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _load_stack(logit_refs, bt: int, bv: int):
    """Stack the per-modality tiles in VMEM ([M, Tb, Vb], f32).  A broadcast
    modality's tile is [1, Vb] and broadcasts over the token rows."""
    return jnp.stack([jnp.broadcast_to(r[...].astype(jnp.float32), (bt, bv))
                      for r in logit_refs])


def _gold_pick(labels, iv, block_v: int):
    """Bool [Tb, Vb]: True where this vocab tile holds the gold column."""
    idx = labels - iv * block_v
    in_tile = (idx >= 0) & (idx < block_v)
    safe = jnp.clip(idx, 0, block_v - 1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32,
                                       (labels.shape[0], block_v), 1)
              == safe[:, None])
    return jnp.where(in_tile[:, None], onehot, False)


def _fused_tile(logits, avail, iv, block_v: int, v_real: int):
    """Availability-averaged mixture tile with padded vocab columns pinned to
    NEG_INF (keeps the fused LSE independent of vocab padding even on rows
    where every modality is unavailable and the mixture degenerates to 0)."""
    denom = jnp.maximum(avail.sum(0), 1e-9)                 # [Tb]
    fused = (jnp.einsum("mtv,mt->tv", logits, avail)
             / denom[:, None])                              # [Tb, Vb]
    col = (jax.lax.broadcasted_iota(jnp.int32, fused.shape, 1)
           + iv * block_v)
    return jnp.where(col < v_real, fused, NEG_INF), denom


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _fwd_kernel(labels_ref, avail_ref, *refs, n_mod: int, block_v: int,
                v_real: int, save_residuals: bool):
    iv = pl.program_id(1)
    nv = pl.num_programs(1)
    logit_refs = refs[:n_mod]
    n_out = 6 if save_residuals else 2
    outs = refs[n_mod:n_mod + n_out]
    mf, sf, gf, mm, sm, gm = refs[n_mod + n_out:]

    @pl.when(iv == 0)
    def _init():
        mf[...] = jnp.full_like(mf, NEG_INF)
        sf[...] = jnp.zeros_like(sf)
        gf[...] = jnp.zeros_like(gf)
        mm[...] = jnp.full_like(mm, NEG_INF)
        sm[...] = jnp.zeros_like(sm)
        gm[...] = jnp.zeros_like(gm)

    bt = labels_ref.shape[0]
    logits = _load_stack(logit_refs, bt, block_v)           # [M, Tb, Vb]
    avail = avail_ref[...].astype(jnp.float32)              # [M, Tb]
    labels = labels_ref[...]                                # [Tb]
    fused, _ = _fused_tile(logits, avail, iv, block_v, v_real)

    # --- streaming logsumexp: fused ---
    tile_max = fused.max(axis=-1)                           # [Tb]
    m_new = jnp.maximum(mf[...], tile_max)
    sf[...] = (sf[...] * jnp.exp(mf[...] - m_new)
               + jnp.exp(fused - m_new[:, None]).sum(-1))
    mf[...] = m_new

    # --- streaming logsumexp: per modality ---
    t_max = logits.max(axis=-1)                             # [M, Tb]
    mm_new = jnp.maximum(mm[...], t_max)
    sm[...] = (sm[...] * jnp.exp(mm[...] - mm_new)
               + jnp.exp(logits - mm_new[..., None]).sum(-1))
    mm[...] = mm_new

    # --- gold logit extraction (label may fall in this vocab tile) ---
    pick = _gold_pick(labels, iv, block_v)
    gf[...] = gf[...] + jnp.where(pick, fused, 0.0).sum(-1)
    gm[...] = gm[...] + jnp.where(pick[None], logits, 0.0).sum(-1)

    @pl.when(iv == nv - 1)
    def _finalize():
        f_lse = mf[...] + jnp.log(sf[...])
        m_lse = mm[...] + jnp.log(sm[...])
        outs[0][...] = (f_lse - gf[...]).astype(outs[0].dtype)
        outs[1][...] = ((m_lse - gm[...]) * avail).astype(outs[1].dtype)
        if save_residuals:
            outs[2][...] = mf[...]
            outs[3][...] = f_lse
            outs[4][...] = mm[...]
            outs[5][...] = m_lse


def _logit_specs(seg, block_t: int, block_v: int):
    """Per-modality input BlockSpecs.  ``seg[m] == 0`` → full [T, V] operand
    tiled (Tb, Vb); ``seg[m] == S`` → compact [B, V] operand whose token tile
    maps onto one batch row (requires Tb | S so tiles never straddle rows)."""
    specs = []
    for s in seg:
        if s:
            assert s % block_t == 0, (s, block_t)
            specs.append(pl.BlockSpec(
                (1, block_v),
                functools.partial(_seg_map, bt=block_t, S=s)))
        else:
            specs.append(pl.BlockSpec((block_t, block_v),
                                      lambda it, iv: (it, iv)))
    return specs


def _seg_map(it, iv, *, bt: int, S: int):
    return ((it * bt) // S, iv)


@functools.partial(jax.jit, static_argnames=(
    "block_t", "block_v", "v_real", "seg", "save_residuals", "interpret"))
def fusion_loss_fwd_pallas(logits, labels, avail, *, block_t: int,
                           block_v: int, v_real: int, seg,
                           save_residuals: bool = False,
                           interpret: bool = False):
    """Variadic forward.  ``logits`` is a tuple of per-modality arrays —
    [T, V], or [B, V] when ``seg[m] = S`` marks a broadcast head (T = B·S);
    labels [T] int32; avail [M, T].  Shapes must tile exactly (the
    differentiable ops.py wrapper pads); ``v_real`` ≤ V marks real vocab
    columns.  Returns (fused_nll [T], modal_nll [M, T]) plus, with
    ``save_residuals``, (fused_max [T], fused_lse [T], modal_max [M, T],
    modal_lse [M, T])."""
    M = len(logits)
    T = labels.shape[0]
    V = logits[0].shape[-1]
    assert T % block_t == 0 and V % block_v == 0, (T, V, block_t, block_v)
    grid = (T // block_t, V // block_v)

    row = lambda it, iv: (it,)                              # noqa: E731
    mrow = lambda it, iv: (0, it)                           # noqa: E731
    out_specs = [pl.BlockSpec((block_t,), row),
                 pl.BlockSpec((M, block_t), mrow)]
    out_shape = [jax.ShapeDtypeStruct((T,), jnp.float32),
                 jax.ShapeDtypeStruct((M, T), jnp.float32)]
    if save_residuals:
        out_specs += [pl.BlockSpec((block_t,), row),
                      pl.BlockSpec((block_t,), row),
                      pl.BlockSpec((M, block_t), mrow),
                      pl.BlockSpec((M, block_t), mrow)]
        out_shape += [jax.ShapeDtypeStruct((T,), jnp.float32),
                      jax.ShapeDtypeStruct((T,), jnp.float32),
                      jax.ShapeDtypeStruct((M, T), jnp.float32),
                      jax.ShapeDtypeStruct((M, T), jnp.float32)]

    kern = functools.partial(_fwd_kernel, n_mod=M, block_v=block_v,
                             v_real=v_real, save_residuals=save_residuals)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_t,), row),
                  pl.BlockSpec((M, block_t), mrow)]
                 + _logit_specs(seg, block_t, block_v),
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((block_t,), jnp.float32),       # mf
            pltpu.VMEM((block_t,), jnp.float32),       # sf
            pltpu.VMEM((block_t,), jnp.float32),       # gf
            pltpu.VMEM((M, block_t), jnp.float32),     # mm
            pltpu.VMEM((M, block_t), jnp.float32),     # sm
            pltpu.VMEM((M, block_t), jnp.float32),     # gm
        ],
        interpret=interpret,
    )(labels, avail, *logits)


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------
def _bwd_kernel(labels_ref, avail_ref, df_ref, dm_ref, flse_ref, mlse_ref,
                *refs, n_mod: int, block_v: int, v_real: int):
    it = pl.program_id(0)
    iv = pl.program_id(1)
    ni = pl.num_programs(0)
    nv = pl.num_programs(1)
    logit_refs = refs[:n_mod]
    dl_refs = refs[n_mod:2 * n_mod]
    gsq_ref, gdot_ref = refs[2 * n_mod:2 * n_mod + 2]
    sq_acc, dot_acc = refs[2 * n_mod + 2:]

    @pl.when((it == 0) & (iv == 0))
    def _init():
        sq_acc[...] = jnp.zeros_like(sq_acc)
        dot_acc[...] = jnp.zeros_like(dot_acc)

    bt = labels_ref.shape[0]
    logits = _load_stack(logit_refs, bt, block_v)           # [M, Tb, Vb]
    avail = avail_ref[...].astype(jnp.float32)              # [M, Tb]
    labels = labels_ref[...]
    df = df_ref[...].astype(jnp.float32)                    # [Tb]
    dm = dm_ref[...].astype(jnp.float32)                    # [M, Tb]
    fused, denom = _fused_tile(logits, avail, iv, block_v, v_real)

    # probabilities from the saved residuals, one tile at a time
    p_f = jnp.exp(fused - flse_ref[...][:, None])           # [Tb, Vb]
    p_m = jnp.exp(logits - mlse_ref[...][..., None])        # [M, Tb, Vb]
    pick = _gold_pick(labels, iv, block_v).astype(jnp.float32)
    base = df[:, None] * (p_f - pick)                       # [Tb, Vb]
    d = ((avail / denom)[..., None] * base[None]
         + (dm * avail)[..., None] * (p_m - pick[None]))    # [M, Tb, Vb]

    for i, r in enumerate(dl_refs):
        r[...] = d[i].astype(r.dtype)
    sq_acc[...] = sq_acc[...] + (d * d).sum((1, 2))
    dot_acc[...] = dot_acc[...] + (d * base[None]).sum((1, 2))

    @pl.when((it == ni - 1) & (iv == nv - 1))
    def _finalize():
        gsq_ref[...] = sq_acc[...]
        gdot_ref[...] = dot_acc[...]


@functools.partial(jax.jit, static_argnames=(
    "block_t", "block_v", "v_real", "seg", "interpret"))
def fusion_loss_bwd_pallas(logits, labels, avail, d_fused, d_modal,
                           fused_lse, modal_lse, *, block_t: int,
                           block_v: int, v_real: int, seg,
                           interpret: bool = False):
    """One blocked pass emitting the logits gradient + ζ/δ partials.

    Inputs mirror the forward (same variadic ``logits``/``seg`` layout) plus
    the loss cotangents ``d_fused`` [T] / ``d_modal`` [M, T] and the saved
    LSE residuals.  Returns (dlogits — one [T, V] f32 array per modality,
    broadcast heads included; gsq [M] = Σ dx_m²; gdot [M] = Σ dx_m·g_fused).
    """
    M = len(logits)
    T = labels.shape[0]
    V = logits[0].shape[-1]
    assert T % block_t == 0 and V % block_v == 0, (T, V, block_t, block_v)
    grid = (T // block_t, V // block_v)

    row = lambda it, iv: (it,)                              # noqa: E731
    mrow = lambda it, iv: (0, it)                           # noqa: E731
    acc = lambda it, iv: (0,)                               # noqa: E731
    kern = functools.partial(_bwd_kernel, n_mod=M, block_v=block_v,
                             v_real=v_real)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((block_t,), row),
                  pl.BlockSpec((M, block_t), mrow),
                  pl.BlockSpec((block_t,), row),
                  pl.BlockSpec((M, block_t), mrow),
                  pl.BlockSpec((block_t,), row),
                  pl.BlockSpec((M, block_t), mrow)]
                 + _logit_specs(seg, block_t, block_v),
        out_specs=[pl.BlockSpec((block_t, block_v),
                                lambda it, iv: (it, iv))] * M
                  + [pl.BlockSpec((M,), acc), pl.BlockSpec((M,), acc)],
        out_shape=[jax.ShapeDtypeStruct((T, V), jnp.float32)] * M
                  + [jax.ShapeDtypeStruct((M,), jnp.float32)] * 2,
        scratch_shapes=[pltpu.VMEM((M,), jnp.float32),
                        pltpu.VMEM((M,), jnp.float32)],
        interpret=interpret,
    )(labels, avail, d_fused, d_modal, fused_lse, modal_lse, *logits)
    return tuple(out[:M]), out[M], out[M + 1]


# ---------------------------------------------------------------------------
# stacked-operand compatibility wrapper (forward only, shapes must tile)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fusion_loss_pallas(logits: jax.Array, labels: jax.Array,
                       avail: jax.Array, *, block_t: int = 128,
                       block_v: int = 2048, interpret: bool = False):
    """logits [M,T,V], labels [T] int32, avail [M,T] -> (fused_nll [T],
    modal_nll [M,T]), both f32.  For the differentiable, padding-aware entry
    point use ``ops.fusion_loss``."""
    M, T, V = logits.shape
    block_t = min(block_t, T)
    block_v = min(block_v, V)
    assert T % block_t == 0 and V % block_v == 0, (T, V, block_t, block_v)
    out = fusion_loss_fwd_pallas(
        tuple(logits[i] for i in range(M)), labels, avail,
        block_t=block_t, block_v=block_v, v_real=V, seg=(0,) * M,
        save_residuals=False, interpret=interpret)
    return out[0], out[1]
