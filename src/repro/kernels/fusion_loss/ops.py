"""Differentiable public wrappers around the fusion-loss kernels.

``fusion_loss`` is the stacked [M, T, V] entry point; it carries a
``jax.custom_vjp`` whose forward saves the online-softmax residuals
(per-row max + log-sum-exp for the fused mixture and each unimodal head) and
whose backward is the one-pass blocked Pallas kernel — softmax probabilities
are never materialised, and ``avail``-masked modalities / zero-cotangent
(sample-mask-padded) rows get exact-zero gradients.  ``fusion_loss_grads``
exposes the same backward with its ζ/δ partials (gsq/gdot) as a public op.

``fused_multimodal_loss`` is the dict front-end with the same
(v_weights, avail, sample_mask) semantics as ``core.fusion.multimodal_loss``
— the training hot path (fl/client.py, ``loss_backend="pallas"``) calls it
per client under the cohort vmap.  Per-modality logits feed the kernel as
separate operands (no [M, B·S, V] stack copy); a broadcast head
(e.g. vision [B, 1, V]) stays its compact [B, V] self via the kernel's
tile→batch-row index map.  ``avail`` entries must be scalars (the per-client
0/1 availability the cohort path uses) — vector per-sample availability
changes the G_m weighting semantics and stays on the XLA path.

Non-divisible ``block_t``/``block_v`` tiles are handled by padding: token
rows pad with avail = 0 (exact-zero loss and gradient), vocab columns pad
with a large-negative logit (exactly zero probability mass).  On CPU both
directions transparently fall back to interpret mode (the TPU kernel is the
deploy target); metrics omit ``fused_logits`` (the kernel never forms the
fused logits tensor — use the XLA path when you need it for accuracy).
"""
from __future__ import annotations

import functools
import math
from typing import Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import (fusion_loss_bwd_pallas, fusion_loss_fwd_pallas,
                     fusion_loss_pallas)

__all__ = ["fusion_loss", "fusion_loss_grads", "fused_multimodal_loss",
           "fusion_loss_pallas"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    return (not _on_tpu()) if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# tile planning + padding.  cfg = (block_t, block_v, interpret, seg) is the
# custom_vjp's static (nondiff) argument: seg[m] = 0 for a full [T, V]
# operand, or S for a compact broadcast head [B, V] (T = B·S).
# ---------------------------------------------------------------------------
def _plan(cfg, T: int, V: int):
    block_t, block_v, _, seg = cfg
    bt = min(block_t, T)
    for s in seg:
        if s:            # tiles must not straddle a broadcast head's rows
            bt = math.gcd(bt, s)
    bv = min(block_v, V)
    return bt, bv, -(-T // bt) * bt, -(-V // bv) * bv


def _neg_big(dtype):
    """Vocab-padding logit: large-negative but summable across M modalities
    without overflowing to inf (0·inf in the mixture einsum would be NaN)."""
    return jnp.asarray(jnp.finfo(dtype).min / 8, dtype)


def _pad_operand(lg, s: int, T: int, V: int, Tp: int, Vp: int):
    if Vp > V:
        lg = jnp.pad(lg, ((0, 0), (0, Vp - V)),
                     constant_values=_neg_big(lg.dtype))
    if not s and Tp > T:
        lg = jnp.pad(lg, ((0, Tp - T), (0, 0)))
    return lg


def _pad_inputs(cfg, logits, labels, avail):
    T = labels.shape[0]
    V = logits[0].shape[-1]
    bt, bv, Tp, Vp = _plan(cfg, T, V)
    seg = cfg[3]
    lg_p = tuple(_pad_operand(lg, s, T, V, Tp, Vp)
                 for lg, s in zip(logits, seg))
    lab_p = jnp.pad(labels, (0, Tp - T)) if Tp > T else labels
    av_p = (jnp.pad(avail, ((0, 0), (0, Tp - T))) if Tp > T else avail)
    return lg_p, lab_p, av_p, (bt, bv, T, V)


# ---------------------------------------------------------------------------
# custom-VJP core
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _fusion_core(cfg, logits, labels, avail):
    out, _ = _fusion_core_fwd(cfg, logits, labels, avail)
    return out


def _fusion_core_fwd(cfg, logits, labels, avail):
    lg_p, lab_p, av_p, (bt, bv, T, V) = _pad_inputs(cfg, logits, labels,
                                                    avail)
    f_nll, m_nll, f_max, f_lse, m_max, m_lse = fusion_loss_fwd_pallas(
        lg_p, lab_p, av_p, block_t=bt, block_v=bv, v_real=V, seg=cfg[3],
        save_residuals=True, interpret=cfg[2])
    res = (logits, labels, avail,
           f_max[:T], f_lse[:T], m_max[:, :T], m_lse[:, :T])
    return (f_nll[:T], m_nll[:, :T]), res


def _bwd_call(cfg, logits, labels, avail, f_lse, m_lse, d_fused, d_modal):
    """Shared padded backward: returns (per-modality dlogits in the
    operands' own layouts/dtypes, gsq [M], gdot [M])."""
    seg = cfg[3]
    lg_p, lab_p, av_p, (bt, bv, T, V) = _pad_inputs(cfg, logits, labels,
                                                    avail)
    Tp = lab_p.shape[0]
    if Tp > T:
        d_fused = jnp.pad(d_fused, (0, Tp - T))
        d_modal = jnp.pad(d_modal, ((0, 0), (0, Tp - T)))
        f_lse = jnp.pad(f_lse, (0, Tp - T))
        m_lse = jnp.pad(m_lse, ((0, 0), (0, Tp - T)))
    dl_p, gsq, gdot = fusion_loss_bwd_pallas(
        lg_p, lab_p, av_p, d_fused, d_modal, f_lse, m_lse,
        block_t=bt, block_v=bv, v_real=V, seg=seg, interpret=cfg[2])
    dl = []
    for lg, s, d in zip(logits, seg, dl_p):
        d = d[:T, :V]
        if s:            # broadcast head: fold the token grid back to [B, V]
            d = d.reshape(-1, s, V).sum(1)
        dl.append(d.astype(lg.dtype))
    return tuple(dl), gsq, gdot


def _fusion_core_bwd(cfg, res, ct):
    logits, labels, avail, _f_max, f_lse, _m_max, m_lse = res
    d_fused, d_modal = ct
    dl, _gsq, _gdot = _bwd_call(cfg, logits, labels, avail, f_lse, m_lse,
                                d_fused, d_modal)
    # labels are integral (float0 cotangent); avail is a mask, not a
    # differentiation surface — its cotangent is defined as zero.
    d_labels = np.zeros(np.shape(labels), jax.dtypes.float0)
    return dl, d_labels, jnp.zeros_like(avail)


_fusion_core.defvjp(_fusion_core_fwd, _fusion_core_bwd)


# ---------------------------------------------------------------------------
# public ops
# ---------------------------------------------------------------------------
def fusion_loss(logits, labels, avail=None, *, block_t: int = 128,
                block_v: int = 2048, interpret: Optional[bool] = None):
    """Differentiable one-pass loss: logits [M,T,V]; labels [T]; avail [M,T]
    (default all-available).  Returns (fused_nll [T], modal_nll [M,T]);
    gradients w.r.t. ``logits`` flow through the blocked backward kernel."""
    M, T, V = logits.shape
    if avail is None:
        avail = jnp.ones((M, T), jnp.float32)
    cfg = (block_t, block_v, _resolve_interpret(interpret), (0,) * M)
    return _fusion_core(cfg, tuple(logits[i] for i in range(M)),
                        labels.astype(jnp.int32),
                        avail.astype(jnp.float32))


def fusion_loss_grads(logits, labels, avail, d_fused, d_modal, *,
                      block_t: int = 128, block_v: int = 2048,
                      interpret: Optional[bool] = None):
    """Backward pass as a public op, partials included.

    Given the loss cotangents ``d_fused`` [T] / ``d_modal`` [M, T], returns
    (dlogits [M, T, V], gsq [M], gdot [M]) where gsq_m = ‖dlogits_m‖² and
    gdot_m = ⟨dlogits_m, g_fused⟩ (g_fused = the fused-CE term of the
    gradient) — the Theorem-1 ζ/δ norm partials in logits space, accumulated
    tile-by-tile inside the same single pass that emits the gradient
    (float64-oracle parity in tests/test_fusion_vjp.py)."""
    M, T, V = logits.shape
    cfg = (block_t, block_v, _resolve_interpret(interpret), (0,) * M)
    lg = tuple(logits[i] for i in range(M))
    labels = labels.astype(jnp.int32)
    avail = avail.astype(jnp.float32)
    _, (_, _, _, _f_max, f_lse, _m_max, m_lse) = _fusion_core_fwd(
        cfg, lg, labels, avail)
    dl, gsq, gdot = _bwd_call(cfg, lg, labels, avail, f_lse, m_lse,
                              jnp.asarray(d_fused, jnp.float32),
                              jnp.asarray(d_modal, jnp.float32))
    return jnp.stack(dl), gsq, gdot


def fused_multimodal_loss(modal_logits: Mapping[str, jax.Array],
                          labels: jax.Array,
                          v_weights: Optional[Mapping[str, float]] = None,
                          avail: Optional[Mapping[str, jax.Array]] = None,
                          sample_mask: Optional[jax.Array] = None, *,
                          block_t: int = 128, block_v: int = 2048,
                          interpret: Optional[bool] = None):
    """Dict front-end matching ``core.fusion.multimodal_loss`` semantics.

    H = F + Σ_m v_m·mean(a_m)·G_m over the sample-masked mean, computed from
    the kernel's per-token (fused_nll, modal_nll) — differentiable end to
    end (the masked means contribute the cotangents; the kernel backward
    does the rest).  Returns (total, {"F", "G_<m>", "G"}).
    """
    names = sorted(modal_logits.keys())
    V = modal_logits[names[0]].shape[-1]
    lab = labels.reshape(-1).astype(jnp.int32)
    T = lab.shape[0]
    lgs, seg = [], []
    for m in names:
        lg = modal_logits[m]
        if lg.shape[:-1] == labels.shape:
            lgs.append(lg.reshape(T, V))
            seg.append(0)
        else:               # broadcast head, e.g. [B, 1, V] vs labels [B, S]
            lgs.append(lg.reshape(-1, V))
            seg.append(int(labels.shape[-1]))
    avs = []
    for m in names:
        a = jnp.asarray(1.0 if avail is None else avail[m], jnp.float32)
        if jnp.ndim(a) != 0:
            raise NotImplementedError(
                "fused_multimodal_loss takes scalar per-modality avail "
                "(the cohort path's 0/1 availability); per-sample vectors "
                "stay on core.fusion.multimodal_loss")
        avs.append(a)
    a_full = jnp.broadcast_to(jnp.stack(avs)[:, None], (len(names), T))

    cfg = (block_t, block_v, _resolve_interpret(interpret), tuple(seg))
    f_nll, m_nll = _fusion_core(cfg, tuple(lgs), lab, a_full)

    if sample_mask is None:
        w = jnp.ones((T,), jnp.float32)
    else:
        w = jnp.broadcast_to(jnp.asarray(sample_mask, jnp.float32),
                             labels.shape).reshape(-1)
    wsum = jnp.maximum(w.sum(), 1e-9)
    F = (f_nll * w).sum() / wsum
    metrics = {"F": F}
    G = jnp.zeros((), jnp.float32)
    for i, m in enumerate(names):
        v = 1.0 if v_weights is None else float(v_weights.get(m, 1.0))
        g = v * (m_nll[i] * w).sum() / wsum
        metrics[f"G_{m}"] = g
        G = G + g
    metrics["G"] = G
    return F + G, metrics
