"""jit'd public wrapper around the fusion-loss kernel.

``fused_multimodal_loss`` reproduces ``core.fusion.multimodal_loss`` totals
(F + Σ v_m·G_m) from the one-pass kernel outputs; on CPU it transparently
falls back to interpret mode (the TPU kernel is the deploy target).
"""
from __future__ import annotations

from typing import Mapping, Optional

import jax
import jax.numpy as jnp

from .kernel import fusion_loss_pallas
from .ref import fusion_loss_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fusion_loss(logits, labels, avail=None, *, block_t: int = 128,
                block_v: int = 2048, interpret: Optional[bool] = None):
    """logits [M,T,V]; labels [T]; avail [M,T] (default all-available)."""
    M, T, V = logits.shape
    if avail is None:
        avail = jnp.ones((M, T), jnp.float32)
    if interpret is None:
        interpret = not _on_tpu()
    return fusion_loss_pallas(logits, labels, avail, block_t=block_t,
                              block_v=block_v, interpret=interpret)


def fused_multimodal_loss(modal_logits: Mapping[str, jax.Array],
                          labels: jax.Array,
                          v_weights: Optional[Mapping[str, float]] = None,
                          **kw):
    """Dict-of-[B,S,V] front-end matching core.fusion.multimodal_loss.

    Returns (total, {"F": ..., "G_<m>": ...}).
    """
    names = sorted(modal_logits.keys())
    B, S, V = modal_logits[names[0]].shape
    stack = jnp.stack([jnp.broadcast_to(modal_logits[m], (B, S, V))
                       for m in names]).reshape(len(names), B * S, V)
    fused_nll, modal_nll = fusion_loss(stack, labels.reshape(-1), **kw)
    F = fused_nll.mean()
    total = F
    metrics = {"F": F}
    for i, m in enumerate(names):
        v = 1.0 if v_weights is None else float(v_weights.get(m, 1.0))
        g = v * modal_nll[i].mean()
        metrics[f"G_{m}"] = g
        total = total + g
    return total, metrics
