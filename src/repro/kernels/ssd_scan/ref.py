"""Pure-jnp oracle for the intra-chunk SSD kernel.

Given one chunk (length Q) per (batch, chunk, head):
  y_diag[t] = Σ_{s<=t} exp(cum_t − cum_s) (C_t·B_s) x_s
  state     = Σ_s exp(cum_Q − cum_s) B_s ⊗ x_s
where cum is the within-chunk cumulative sum of dt*A.
"""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(x, cum, Bm, Cm):
    """x: [B,nc,Q,nh,hp] (dt-weighted input), cum: [B,nc,Q,nh],
    Bm/Cm: [B,nc,Q,N].  Returns (y_diag [B,nc,Q,nh,hp],
    states [B,nc,nh,N,hp])."""
    Q = x.shape[2]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # [B,nc,Q,Q,nh]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    L = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("bctn,bcsn->bcts", Cm, Bm)
    y_diag = jnp.einsum("bctsh,bcts,bcshp->bcthp", L, scores, x)
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)
    states = jnp.einsum("bcsn,bcsh,bcshp->bchnp", Bm, decay_to_end, x)
    return y_diag, states
