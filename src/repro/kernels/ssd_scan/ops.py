"""Full chunked-SSD forward built on the Pallas intra-chunk kernel.

Matches ``models.mamba2.ssd_chunked`` (the XLA path): the kernel computes the
block-diagonal term and the chunk summary states; the O(S/chunk) inter-chunk
recurrence and the off-diagonal contribution remain cheap jnp ops.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas


def ssd_forward(x, dt, A, Bm, Cm, chunk: int, *,
                interpret: Optional[bool] = None):
    """Same contract as models.mamba2.ssd_chunked.

    x: [B,S,nh,hp]; dt: [B,S,nh] fp32; A: [nh]; Bm/Cm: [B,S,N].
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    Bsz, S, nh, hp = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    xd = x.astype(jnp.float32) * dt[..., None]
    dtA = dt * A[None, None, :]
    cum = jnp.cumsum(dtA.reshape(Bsz, nc, Q, nh), axis=2)
    xc = xd.reshape(Bsz, nc, Q, nh, hp)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)

    y_diag, states = ssd_chunk_pallas(xc, cum, Bc, Cc, interpret=interpret)

    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def body(h, inp):
        st, dec = inp
        h_before = h
        h = h * dec[..., None, None] + st
        return h, h_before

    h0 = jnp.zeros((Bsz, nh, N, hp), jnp.float32)
    _, h_prev = jax.lax.scan(
        body, h0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)
    y_off = jnp.einsum("bctn,bcth,bchnp->bcthp", Cc, jnp.exp(cum), h_prev)
    return (y_diag + y_off).reshape(Bsz, S, nh, hp).astype(x.dtype)
