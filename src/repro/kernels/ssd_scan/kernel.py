"""Pallas TPU kernel: Mamba2 SSD intra-chunk contraction (arXiv:2405.21060).

The chunked SSD algorithm splits the sequence into chunks of Q tokens; the
intra-chunk (block-diagonal) term is an attention-like contraction masked by
the decay matrix L[t,s] = exp(cum_t − cum_s), and the per-chunk summary state
feeds the O(S/Q) inter-chunk recurrence (kept in ``ops.py`` as a lax.scan).

This kernel fuses, per (batch, chunk, head):   decay-matrix construction,
C·Bᵀ scores, masking, the [Q,Q]x[Q,hp] matmul, AND the chunk-state
[N,Q]x[Q,hp] matmul — one VMEM round trip for x/B/C instead of five HBM
passes in the XLA path.  Q=chunk defaults to 128/256 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, cum_ref, b_ref, c_ref, y_ref, st_ref, *, Q: int):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)           # [Q, hp]
    cum = cum_ref[0, 0, :, 0].astype(jnp.float32)          # [Q]
    Bm = b_ref[0, 0].astype(jnp.float32)                   # [Q, N]
    Cm = c_ref[0, 0].astype(jnp.float32)                   # [Q, N]

    tri = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    # mask the exponent: upper-tri diffs overflow exp (cf. mamba2.py note)
    L = jnp.exp(jnp.where(tri, cum[:, None] - cum[None, :], -jnp.inf))
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = L * scores                                         # [Q, Q]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)                     # [Q]
    xw = x * decay_end[:, None]
    st = jax.lax.dot_general(Bm, xw, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # [N, hp]
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(x, cum, Bm, Cm, *, interpret: bool = False):
    """x: [B,nc,Q,nh,hp] (dt-weighted), cum: [B,nc,Q,nh], Bm/Cm: [B,nc,Q,N].

    Returns (y_diag [B,nc,Q,nh,hp] f32, states [B,nc,nh,N,hp] f32).
    """
    B, nc, Q, nh, hp = x.shape
    N = Bm.shape[-1]
    grid = (B, nc, nh)
    kern = functools.partial(_kernel, Q=Q)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, hp), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, c, h: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, hp), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, N, hp), lambda b, c, h: (b, c, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc, Q, nh, hp), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, nh, N, hp), jnp.float32),
        ],
        interpret=interpret,
    )(x, cum, Bm, Cm)
