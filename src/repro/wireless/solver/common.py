"""Shared pieces of the batched JCSBA solver backends.

The solver evaluates a whole antibody population A ∈ {0,1}^{P×K} per
generation: the KKT bandwidth subproblem (P4.2') is a fixed-iteration
bisection vmapped over candidates and masked over participants, and the
Theorem-1 bound term + Lyapunov energy term fuse into the same program.

Two backends implement the identical algorithm on the identical random draws
(``jax.random`` bits, see ``jaxsolver.make_draws``):

* ``ref.py``       — float64 numpy, the readable reference;
* ``jaxsolver.py`` — float32 jnp, one jitted program per round.

Parity between them (and against the legacy scalar ``bandwidth.allocate`` /
``immune.immune_search`` path, kept as ``solver="seq"``) is asserted in
``tests/test_solver_parity.py``.

Numerical conventions shared by both backends (mirrored exactly so the two
trajectories stay bit-comparable up to float32 rounding):

* bisections run a *fixed* iteration count on a *fixed* bracket instead of
  the legacy expand-then-break loops.  The brackets exploit that no useful
  allocation exceeds B_max: φ⁻¹ bisects on [B_min, B_max] (every B_k ≤ B_max
  at the KKT point, so clamping there never moves the κ root) and the B_min
  solve bisects on [B_LO, 2·B_max] — a B_min driven to the cap just renders
  the candidate infeasible via the Σ B_min ≤ B_max check (Eq. 42), where only
  "> B_max", not the magnitude, matters;
* the κ bisection runs in log(−κ) space: κ* spans many decades (φ values from
  ~−1e9 down to ~−1e-20) and linear halving cannot resolve that in a fixed
  budget;
* φ's small-x cancellation (x/(1+x) − log1p(x) for x ≪ 1) is replaced by its
  series −x²/2 + (2/3)x³ − (3/4)x⁴ below ``PHI_SERIES_X`` so float32 keeps
  ~5 significant digits;
* B_min is inflated by ``BMIN_SAFETY`` so float32 allocations keep a real
  latency margin (the runtime's feasibility check is strict).
"""
from __future__ import annotations

import dataclasses

import numpy as np

TOL_B = 1.0            # [Hz] — same absolute tolerance as bandwidth._TOL_B
B_LO = 1e-3            # [Hz] lower bracket end for the B_min bisection
B_CAP = 1e12           # [Hz] sentinel B_min for latency-infeasible clients
BMIN_SAFETY = 1e-4     # relative inflation of B_min (float32 latency margin)
KAPPA_TINY = 1e-30     # |κ| upper-bracket end (κ → 0⁻)
PHI_SERIES_X = 0.02    # switch φ's numerator to its series below this x


@dataclasses.dataclass(frozen=True)
class SolverHyper:
    """Immune-search hyper-parameters (Algorithm 2 header) + fixed iteration
    budgets for the bisections.  Frozen/hashable so it can be a static jit
    argument."""
    S: int = 20            # population size
    G: int = 10            # generations
    mu: int = 5            # clone factor
    z: float = 0.175       # mutation probability
    iota: float = 4.0      # affinity sharpening exponent
    dis: int = 2           # Hamming similarity threshold (Eq. 51)
    eps1: float = 1.0      # incentive: affinity weight (Eq. 53)
    eps2: float = 0.15     # incentive: concentration weight (Eq. 53)
    n_bisect_b: int = 30   # iterations for every B-space bisection
    n_bisect_k: int = 40   # iterations for the log-space κ bisection

    @property
    def n_elite(self) -> int:
        return max(self.S // self.mu, 1)

    @property
    def n_clones(self) -> int:
        return self.n_elite * self.mu

    @property
    def n_cand(self) -> int:
        return self.n_clones + self.n_elite

    @property
    def n_keep(self) -> int:
        # never more than the clone+elite pool provides (small S with large μ)
        return min(self.S - self.n_elite, self.n_cand)

    @property
    def n_fresh(self) -> int:
        return self.S - self.n_keep


def build_solver_data(h, Q, cost, params, bound, V: float) -> dict:
    """Per-round numerical context for either backend, as plain numpy.

    ``cost``/``params`` are ``wireless.cost.ClientCost`` /
    ``wireless.params.WirelessParams``; ``bound`` is a
    ``core.convergence.BoundState`` or None (bound term ≡ 0, M = 0)."""
    h = np.asarray(h, np.float64)
    K = len(h)
    if bound is not None:
        snap = bound.snapshot()
        eta, rho = float(bound.eta), float(bound.rho)
    else:
        snap = {"zeta2": np.zeros(0), "delta2": np.zeros((0, K)),
                "wbar": np.zeros((0, K)), "has": np.zeros((0, K), bool),
                "D": np.zeros(K)}
        eta = rho = 0.0
    return {
        "Q": np.asarray(Q, np.float64),
        "gamma": np.asarray(cost.gamma_bits, np.float64),
        "h": h,
        "tau_rem": np.asarray(cost.tau_residual(params), np.float64),
        "e_cmp": np.asarray(cost.e_cmp, np.float64),
        "B_max": float(params.B_max),
        "p_tx": float(params.p_tx),
        "N0": float(params.N0),
        "V": float(V),
        "eta": eta,
        "rho": rho,
        **snap,
    }
