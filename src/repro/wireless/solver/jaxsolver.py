"""Fused jitted JCSBA solver — the whole server-side decision layer (immune
search over antibodies × KKT bandwidth bisection × Theorem-1 bound) as one
JAX program per round.

The program evaluates the full antibody population per generation: J₂(a) for
every candidate is computed by a candidate-vmapped, participant-masked
fixed-iteration bisection stack (see ``common`` for the numerical
conventions), the bound term comes from ``core.convergence.objective_batched``
and everything runs under a single ``jax.jit`` with ``lax.fori_loop`` over
generations.  Random draws come from ``make_draws`` (``jax.random``) so the
float64 numpy mirror in ``ref.py`` can consume the identical bits.

``solve_core`` is the pure jnp entry point — ``policies.JCSBAPolicy`` builds
its traced step on it and benchmark sweep drivers wrap it in their own
``vmap``/``scan`` (scenario grids × rounds); ``solve_round`` is the
standalone numpy-in/numpy-out per-solve call kept for the jax↔np parity
suite (tests/test_solver_parity.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ...core.convergence import objective_batched
from .common import (B_CAP, B_LO, BMIN_SAFETY, KAPPA_TINY, PHI_SERIES_X,
                     TOL_B, SolverHyper)

LN2 = float(np.log(2.0))

_BOOL_KEYS = ("has",)


def to_device(data: dict) -> dict:
    """numpy solver-data dict (``common.build_solver_data``) → float32 jnp."""
    out = {}
    for k, v in data.items():
        out[k] = jnp.asarray(v) if k in _BOOL_KEYS else \
            jnp.asarray(v, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# physics: rate / φ / B_min — fixed-bracket bisections (see common docstring)
# ---------------------------------------------------------------------------
def rate(B, h, p_tx, N0):
    """Shannon/FDMA uplink rate r(B) (Eq. 13), jnp.  Public: the fused round
    engine and the sweep drivers reuse it for post-solve latency/energy."""
    x = p_tx * h / (B * N0)
    return B * jnp.log1p(x) / LN2


_rate = rate        # internal alias used throughout the bisection stack


def _phi(B, Q, gamma, h, p_tx, N0):
    """φ = ∂J₃/∂B (Eq. 37), series-stabilised for small x."""
    x = p_tx * h / (B * N0)
    ln1x = jnp.log1p(x)
    exact = x / (1.0 + x) - ln1x
    series = x * x * (-0.5 + x * (2.0 / 3.0 - 0.75 * x))
    num = jnp.where(x < PHI_SERIES_X, series, exact)
    return Q * p_tx * gamma * LN2 * num / (B * B * ln1x * ln1x)


def _bmin(gamma, h, tau_rem, B_max, p_tx, N0, hp: SolverHyper):
    """Per-client B with r(B) = Γ/τ_rem (Eq. 41).  Returns (bmin [K], ok [K]).

    The bracket tops out at 2·B_max: a B_min beyond that (or a latency-
    infeasible client, which gets the B_CAP sentinel) kills any candidate via
    the Σ B_min ≤ B_max check, where only "> B_max" matters."""
    target = gamma / jnp.where(tau_rem > 0, tau_rem, 1.0)
    ceiling = p_tx * h / (N0 * LN2)
    ok = (tau_rem > 0) & (target < ceiling * (1 - 1e-12))
    lo = jnp.full_like(h, B_LO)
    hi = jnp.full_like(h, 2 * B_max)

    def body(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        under = _rate(mid, h, p_tx, N0) < target
        return jnp.where(under, mid, lo), jnp.where(under, hi, mid)

    _, hi = lax.fori_loop(0, hp.n_bisect_b, body, (lo, hi))
    return jnp.where(ok, hi * (1 + BMIN_SAFETY), B_CAP), ok


def _phi_inv(kappa, bmin, phi_b, Q, gamma, h, B_max, p_tx, N0,
             hp: SolverHyper):
    """B ≥ B_min with φ(B) = κ for every (candidate, client).

    kappa: [P, 1]; per-client arrays [K].  Clients with φ(B_min) ≥ κ are
    pinned at B_min (E1/E2 in the paper's case analysis).  The bracket is
    [B_min, B_max]: every B_k ≤ B_max at the KKT point, so clamping there
    never moves the κ root and keeps the fixed iteration budget small."""
    pinned = phi_b >= kappa                               # [P, K]
    lo = jnp.broadcast_to(bmin, pinned.shape)
    hi = jnp.full(pinned.shape, B_max, bmin.dtype)

    def body(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        under = _phi(mid, Q, gamma, h, p_tx, N0) < kappa
        return jnp.where(under, mid, lo), jnp.where(under, hi, mid)

    lo, hi = lax.fori_loop(0, hp.n_bisect_b, body, (lo, hi))
    return jnp.where(pinned, bmin, 0.5 * (lo + hi))


def allocate_batch(A, bmin, ok, Q, gamma, h, B_max, p_tx, N0,
                   hp: SolverHyper):
    """Solve P4.2' for a whole population A ∈ {0,1}^{P×K} at once.

    Returns (B [P, K], feasible [P]); infeasibility is a mask, not None —
    infeasible rows carry B = 0."""
    A = jnp.asarray(A, bool)
    Af = A.astype(bmin.dtype)
    U = Af.sum(-1)                                        # [P]
    total_min = (Af * bmin).sum(-1)
    feasible = (~(A & ~ok).any(-1)) & (total_min <= B_max + TOL_B)
    at_eq = total_min >= B_max - TOL_B                    # (42) with equality
    phi_b = _phi(bmin, Q, gamma, h, p_tx, N0)             # [K]
    active = A & (Q > 0)

    # κ* bisection in log(−κ) space: total Σ B_k(κ) is monotone increasing
    # in κ, and κ spans many decades, so geometric halving is required to
    # converge in a fixed budget.  u_a ↔ total < B_max, u_b ↔ total ≥ B_max.
    k_lo = jnp.min(jnp.where(active, phi_b, 0.0), axis=-1)
    k_lo = jnp.minimum(k_lo, -1e-35)      # keep log finite; dummy if ¬active
    u_a = jnp.log(-k_lo)
    u_b = jnp.full_like(u_a, float(np.log(KAPPA_TINY)))

    def kbody(_, uu):
        u_a, u_b = uu
        u_mid = 0.5 * (u_a + u_b)
        kap = -jnp.exp(u_mid)[:, None]
        t = (Af * _phi_inv(kap, bmin, phi_b, Q, gamma, h, B_max, p_tx, N0,
                           hp)).sum(-1)
        under = t < B_max
        return jnp.where(under, u_mid, u_a), jnp.where(under, u_b, u_mid)

    _, u_b = lax.fori_loop(0, hp.n_bisect_k, kbody, (u_a, u_b))
    B = _phi_inv(-jnp.exp(u_b)[:, None], bmin, phi_b, Q, gamma, h,
                 B_max, p_tx, N0, hp)
    B = jnp.where(A, B, 0.0)

    # distribute residual rounding slack (keeps Σ = B_max), as in the legacy
    # scalar path: over unpinned clients if any, else over all participants
    slack = B_max - B.sum(-1)                             # [P]
    freem = A & (B > bmin + TOL_B)
    nfree = freem.sum(-1)
    add = jnp.where((nfree > 0)[:, None],
                    freem * (slack / jnp.maximum(nfree, 1))[:, None],
                    Af * (slack / jnp.maximum(U, 1))[:, None])
    B_kkt = jnp.where(A, jnp.maximum(B + add, bmin), 0.0)

    B_eq = jnp.where(A, bmin, 0.0)
    # all-participants-Q≤0: objective flat, split the slack evenly
    B_q0 = jnp.where(
        A, bmin + ((B_max - total_min) / jnp.maximum(U, 1))[:, None], 0.0)
    B = jnp.where(at_eq[:, None], B_eq,
                  jnp.where(active.any(-1)[:, None], B_kkt, B_q0))
    return jnp.where(feasible[:, None], B, 0.0), feasible


# ---------------------------------------------------------------------------
# J₂(a) for a population, fusing bound + energy terms
# ---------------------------------------------------------------------------
def objective_batch(A, B, feasible, data):
    """J₂(a) = V·(Theorem-1 objective) + Σ_k a_k Q_k (e_com + e_cmp);
    infeasible rows → +inf."""
    A = jnp.asarray(A, bool)
    Af = A.astype(B.dtype)
    r = _rate(jnp.maximum(B, B_LO), data["h"], data["p_tx"], data["N0"])
    tcom = jnp.where(A, data["gamma"] / jnp.maximum(r, 1e-30), 0.0)
    energy = (Af * data["Q"] * (data["p_tx"] * tcom
                                + data["e_cmp"])).sum(-1)
    bound = objective_batched(Af, data["zeta2"], data["delta2"],
                              data["wbar"], data["has"], data["D"],
                              data["eta"], data["rho"])
    return jnp.where(feasible, data["V"] * bound + energy, jnp.inf)


def _affinity(vals, hp: SolverHyper):
    """Eq. 50 affinity: min-max normalised, sharpened; infeasible → 0."""
    finite = jnp.isfinite(vals)
    jmax = jnp.max(jnp.where(finite, vals, -jnp.inf))
    jmin = jnp.min(jnp.where(finite, vals, jnp.inf))
    span = jnp.maximum(jmax - jmin, 1e-12)
    base = jnp.maximum((jmax - vals) / span, 0.0) + 1e-6
    aff = jnp.where(finite, base ** hp.iota, 0.0)
    return jnp.where(finite.any(), aff, jnp.zeros_like(vals))


# ---------------------------------------------------------------------------
# immune search over the population (Algorithm 2), fully on device
# ---------------------------------------------------------------------------
def make_draws(key, K: int, hp: SolverHyper):
    """All random bits for one solve.  Called inside the jitted program and,
    eagerly, by the numpy reference — identical bits either way."""
    k1, k2, k3 = jax.random.split(key, 3)
    init = jax.random.bernoulli(k1, 0.5, (hp.S, K))
    mut = jax.random.bernoulli(k2, hp.z, (hp.G, hp.n_clones, K))
    fresh = jax.random.bernoulli(k3, 0.5, (hp.G, hp.n_fresh, K))
    return init, mut, fresh


def solve_core(data: dict, seeds, key, hp: SolverHyper):
    """One JCSBA solve: (a*, J*, B*) for one round's ``data`` (jnp, float32).

    ``seeds`` [2, K] bool: warm-start antibody rows written over the first
    population rows (row 1 is conventionally the all-zeros antibody, so an
    empty schedule is always evaluated and J* is always finite).

    Callers may inject a precomputed per-client bisection as ``data["bmin"]``
    / ``data["bmin_ok"]`` — the fused round engine computes ``_bmin`` shard-
    locally under a client-sharded mesh and ``all_gather``s the [K] result
    (the bisection is elementwise, so the injected values are bit-identical
    to the inline ones)."""
    K = data["Q"].shape[0]
    if "bmin" in data:
        bmin, ok = data["bmin"], data["bmin_ok"]
    else:
        bmin, ok = _bmin(data["gamma"], data["h"], data["tau_rem"],
                         data["B_max"], data["p_tx"], data["N0"], hp)

    def J_batch(A):
        B, feas = allocate_batch(A, bmin, ok, data["Q"], data["gamma"],
                                 data["h"], data["B_max"], data["p_tx"],
                                 data["N0"], hp)
        return objective_batch(A, B, feas, data)

    def fold_best(pop, vals, best_a, best_J):
        i = jnp.argmin(vals)
        better = vals[i] < best_J
        return (jnp.where(better, pop[i], best_a),
                jnp.where(better, vals[i], best_J))

    init, mut, fresh = make_draws(key, K, hp)
    seeds = jnp.asarray(seeds, bool)
    pop0 = init.at[0].set(seeds[0]).at[1].set(seeds[1])

    # J is purely row-wise, so the population's values are carried across
    # generations and only *new* genotypes (clones/mutants + fresh rows) are
    # evaluated — the batched analogue of the sequential path's memoisation.
    def gen(g, carry):
        pop, vals, best_a, best_J = carry
        best_a, best_J = fold_best(pop, vals, best_a, best_J)
        aff = _affinity(vals, hp)
        ham = (pop[:, None, :] ^ pop[None, :, :]).sum(-1)
        con = (ham <= hp.dis).astype(aff.dtype).mean(-1)      # Eq. 51-52
        inc = hp.eps1 * aff - hp.eps2 * con                   # Eq. 53
        elites = pop[jnp.argsort(-inc)[:hp.n_elite]]
        clones = jnp.repeat(elites, hp.mu, axis=0)            # μ-fold cloning
        mutants = clones ^ mut[g]
        cand = jnp.concatenate([mutants, elites], axis=0)
        cand_vals = J_batch(cand)
        cand_aff = _affinity(cand_vals, hp)
        order = jnp.argsort(-cand_aff)[:hp.n_keep]
        pop = jnp.concatenate([cand[order], fresh[g]], axis=0)
        vals = jnp.concatenate([cand_vals[order], J_batch(fresh[g])])
        return pop, vals, best_a, best_J

    carry = (pop0, J_batch(pop0), jnp.zeros(K, bool),
             jnp.asarray(jnp.inf, jnp.float32))
    pop, vals, best_a, best_J = lax.fori_loop(0, hp.G, gen, carry)
    best_a, best_J = fold_best(pop, vals, best_a, best_J)     # final gen check
    B, _ = allocate_batch(best_a[None], bmin, ok, data["Q"], data["gamma"],
                          data["h"], data["B_max"], data["p_tx"],
                          data["N0"], hp)
    return best_a, best_J, B[0]


@partial(jax.jit, static_argnames="hp")
def _solve_jit(data, seeds, key, hp: SolverHyper):
    return solve_core(data, seeds, key, hp)


def solve_round(data: dict, seeds: np.ndarray, seed_int: int,
                hp: SolverHyper):
    """Host-facing per-round solve: numpy in, numpy out.

    Compiles once per (K, M, hp) signature; subsequent rounds re-use the
    cached executable."""
    key = jax.random.PRNGKey(seed_int)
    a, J, B = _solve_jit(to_device(data), jnp.asarray(seeds, bool), key, hp)
    return np.asarray(a), float(J), np.asarray(B, np.float64)
