"""Population-batched JCSBA solver subsystem (Algorithm 2 + P4.2' + Theorem 1
as one fused program per round).

* ``common``    — hyper-parameters, numerical conventions, round-data builder
* ``jaxsolver`` — float32 jitted backend (``solver="jax"``)
* ``ref``       — float64 numpy mirror     (``solver="np"``)

The legacy scalar path (``wireless.bandwidth`` + ``wireless.immune``) stays
available as ``solver="seq"`` in ``schedulers.JCSBAScheduler``.
"""
from .common import SolverHyper, build_solver_data
from .jaxsolver import solve_core, solve_round
from .ref import solve_round_np

__all__ = ["SolverHyper", "build_solver_data", "solve_core", "solve_round",
           "solve_round_np"]
