"""Float64 numpy reference for the batched JCSBA solver.

Mirrors ``jaxsolver`` operation-for-operation — same fixed-iteration
bisections, same brackets, same series-stabilised φ, same stable sorts, and
the *same random bits* (it consumes ``jaxsolver.make_draws`` eagerly).  The
two backends therefore walk identical immune-search trajectories up to
float32 rounding, which is what ``tests/test_solver_parity.py`` pins down.

This is the ``solver="np"`` backend of ``schedulers.JCSBAScheduler`` and the
readable specification of the batched algorithm; the original scalar
implementations (``bandwidth.allocate``, ``immune.immune_search``) remain the
mathematical reference for the *sequential* path (``solver="seq"``).
"""
from __future__ import annotations

import numpy as np

from .common import (B_CAP, B_LO, BMIN_SAFETY, KAPPA_TINY, PHI_SERIES_X,
                     TOL_B, SolverHyper)

LN2 = float(np.log(2.0))


# ---------------------------------------------------------------------------
# physics — numpy twins of jaxsolver._rate / _phi / _bmin / _phi_inv
# ---------------------------------------------------------------------------
def _rate(B, h, p_tx, N0):
    x = p_tx * h / (B * N0)
    return B * np.log1p(x) / LN2


def _phi(B, Q, gamma, h, p_tx, N0):
    x = p_tx * h / (B * N0)
    ln1x = np.log1p(x)
    exact = x / (1.0 + x) - ln1x
    series = x * x * (-0.5 + x * (2.0 / 3.0 - 0.75 * x))
    num = np.where(x < PHI_SERIES_X, series, exact)
    return Q * p_tx * gamma * LN2 * num / (B * B * ln1x * ln1x)


def bmin_np(gamma, h, tau_rem, B_max, p_tx, N0, hp: SolverHyper):
    """(bmin [K], ok [K]) — vectorized Eq. 41 solve, fixed bracket/iters."""
    gamma = np.asarray(gamma, np.float64)
    h = np.asarray(h, np.float64)
    tau_rem = np.asarray(tau_rem, np.float64)
    target = gamma / np.where(tau_rem > 0, tau_rem, 1.0)
    ceiling = p_tx * h / (N0 * LN2)
    ok = (tau_rem > 0) & (target < ceiling * (1 - 1e-12))
    lo = np.full_like(h, B_LO)
    hi = np.full_like(h, 2 * B_max)
    for _ in range(hp.n_bisect_b):
        mid = 0.5 * (lo + hi)
        under = _rate(mid, h, p_tx, N0) < target
        lo = np.where(under, mid, lo)
        hi = np.where(under, hi, mid)
    return np.where(ok, hi * (1 + BMIN_SAFETY), B_CAP), ok


def _phi_inv(kappa, bmin, phi_b, Q, gamma, h, B_max, p_tx, N0,
             hp: SolverHyper):
    pinned = phi_b >= kappa                               # [P, K]
    lo = np.broadcast_to(bmin, pinned.shape).copy()
    hi = np.full(pinned.shape, B_max)
    for _ in range(hp.n_bisect_b):
        mid = 0.5 * (lo + hi)
        under = _phi(mid, Q, gamma, h, p_tx, N0) < kappa
        lo = np.where(under, mid, lo)
        hi = np.where(under, hi, mid)
    return np.where(pinned, bmin, 0.5 * (lo + hi))


def allocate_np(A, bmin, ok, Q, gamma, h, B_max, p_tx, N0,
                hp: SolverHyper):
    """Population P4.2' solve: (B [P, K], feasible [P]).  Numpy float64."""
    A = np.asarray(A, bool)
    Af = A.astype(np.float64)
    U = Af.sum(-1)
    total_min = (Af * bmin).sum(-1)
    feasible = (~(A & ~ok).any(-1)) & (total_min <= B_max + TOL_B)
    at_eq = total_min >= B_max - TOL_B
    phi_b = _phi(bmin, Q, gamma, h, p_tx, N0)
    active = A & (Q > 0)

    k_lo = np.min(np.where(active, phi_b, 0.0), axis=-1)
    k_lo = np.minimum(k_lo, -1e-35)
    u_a = np.log(-k_lo)
    u_b = np.full_like(u_a, np.log(KAPPA_TINY))
    for _ in range(hp.n_bisect_k):
        u_mid = 0.5 * (u_a + u_b)
        kap = -np.exp(u_mid)[:, None]
        t = (Af * _phi_inv(kap, bmin, phi_b, Q, gamma, h, B_max, p_tx, N0,
                           hp)).sum(-1)
        under = t < B_max
        u_a = np.where(under, u_mid, u_a)
        u_b = np.where(under, u_b, u_mid)
    B = _phi_inv(-np.exp(u_b)[:, None], bmin, phi_b, Q, gamma, h,
                 B_max, p_tx, N0, hp)
    B = np.where(A, B, 0.0)

    slack = B_max - B.sum(-1)
    freem = A & (B > bmin + TOL_B)
    nfree = freem.sum(-1)
    add = np.where((nfree > 0)[:, None],
                   freem * (slack / np.maximum(nfree, 1))[:, None],
                   Af * (slack / np.maximum(U, 1))[:, None])
    B_kkt = np.where(A, np.maximum(B + add, bmin), 0.0)

    B_eq = np.where(A, bmin, 0.0)
    B_q0 = np.where(
        A, bmin + ((B_max - total_min) / np.maximum(U, 1))[:, None], 0.0)
    B = np.where(at_eq[:, None], B_eq,
                 np.where(active.any(-1)[:, None], B_kkt, B_q0))
    return np.where(feasible[:, None], B, 0.0), feasible


# ---------------------------------------------------------------------------
# Theorem-1 objective — float64 mirror of convergence.objective_batched
# ---------------------------------------------------------------------------
def bound_objective_np(A, zeta2, delta2, wbar, has, D, eta, rho,
                       gamma: float = 1.0):
    Af = np.asarray(A, np.float64)
    part = has[None] & (Af[:, None, :] > 0.5)             # [P, M, K]
    sched = part.any(-1)
    A1 = ((~sched) * zeta2).sum(-1)
    wt_raw = np.where(part, D, 0.0)
    denom = wt_raw.sum(-1, keepdims=True)
    wt = np.where(denom > 0, wt_raw / np.maximum(denom, 1e-30), 0.0)
    cover = (Af[:, None, :] * wbar).sum(-1)
    coeff = wt + wbar - 2.0 * Af[:, None, :] * wbar
    A2_m = 2.0 * (1.0 - cover) * (coeff * delta2).sum(-1)
    A2 = np.maximum((sched * A2_m).sum(-1), 0.0)
    covered = (sched * zeta2).sum(-1)
    c = (2 * eta - gamma * eta ** 2) / 2.0
    return eta * rho * np.sqrt(A1 + A2) - c * covered


def objective_np(A, B, feasible, data: dict):
    """J₂(a) for the population; infeasible rows → +inf."""
    A = np.asarray(A, bool)
    Af = A.astype(np.float64)
    r = _rate(np.maximum(B, B_LO), data["h"], data["p_tx"], data["N0"])
    tcom = np.where(A, data["gamma"] / np.maximum(r, 1e-30), 0.0)
    energy = (Af * data["Q"] * (data["p_tx"] * tcom
                                + data["e_cmp"])).sum(-1)
    bound = bound_objective_np(Af, data["zeta2"], data["delta2"],
                               data["wbar"], data["has"], data["D"],
                               data["eta"], data["rho"])
    return np.where(feasible, data["V"] * bound + energy, np.inf)


def _affinity(vals, hp: SolverHyper):
    finite = np.isfinite(vals)
    if not finite.any():
        return np.zeros_like(vals)
    jmax = np.max(np.where(finite, vals, -np.inf))
    jmin = np.min(np.where(finite, vals, np.inf))
    span = max(jmax - jmin, 1e-12)
    base = np.maximum((jmax - vals) / span, 0.0) + 1e-6
    return np.where(finite, base ** hp.iota, 0.0)


# ---------------------------------------------------------------------------
# immune search (Algorithm 2), batched — mirrors jaxsolver.solve_core
# ---------------------------------------------------------------------------
def solve_round_np(data: dict, seeds: np.ndarray, seed_int: int,
                   hp: SolverHyper):
    """One JCSBA solve on the numpy backend: (a* [K] bool, J*, B* [K])."""
    import jax

    from .jaxsolver import make_draws

    K = len(data["Q"])
    bmin, ok = bmin_np(data["gamma"], data["h"], data["tau_rem"],
                       data["B_max"], data["p_tx"], data["N0"], hp)

    def J_batch(A):
        B, feas = allocate_np(A, bmin, ok, data["Q"], data["gamma"],
                              data["h"], data["B_max"], data["p_tx"],
                              data["N0"], hp)
        return objective_np(A, B, feas, data)

    init, mut, fresh = (np.asarray(d) for d in
                        make_draws(jax.random.PRNGKey(seed_int), K, hp))
    pop = init.copy()
    pop[0], pop[1] = np.asarray(seeds[0], bool), np.asarray(seeds[1], bool)

    best_a, best_J = np.zeros(K, bool), np.inf

    def fold_best(pop, vals, best_a, best_J):
        i = int(np.argmin(vals))
        if vals[i] < best_J:
            return pop[i].copy(), vals[i]
        return best_a, best_J

    # mirror of the jax path's carried values: J is row-wise, so kept rows
    # re-use the candidate values computed when they were selected
    vals = J_batch(pop)
    for g in range(hp.G):
        best_a, best_J = fold_best(pop, vals, best_a, best_J)
        aff = _affinity(vals, hp)
        ham = (pop[:, None, :] ^ pop[None, :, :]).sum(-1)
        con = (ham <= hp.dis).astype(np.float64).mean(-1)     # Eq. 51-52
        inc = hp.eps1 * aff - hp.eps2 * con                   # Eq. 53
        elites = pop[np.argsort(-inc, kind="stable")[:hp.n_elite]]
        clones = np.repeat(elites, hp.mu, axis=0)             # μ-fold cloning
        mutants = clones ^ mut[g]
        cand = np.concatenate([mutants, elites], axis=0)
        cand_vals = J_batch(cand)
        cand_aff = _affinity(cand_vals, hp)
        order = np.argsort(-cand_aff, kind="stable")[:hp.n_keep]
        pop = np.concatenate([cand[order], fresh[g]], axis=0)
        vals = np.concatenate([cand_vals[order], J_batch(fresh[g])])

    best_a, best_J = fold_best(pop, vals, best_a, best_J)     # final gen
    B, _ = allocate_np(best_a[None], bmin, ok, data["Q"], data["gamma"],
                       data["h"], data["B_max"], data["p_tx"],
                       data["N0"], hp)
    return best_a, float(best_J), B[0]
