"""Client scheduling strategies.

* ``JCSBAScheduler`` — the paper's algorithm: per-round P3 objective
  J₂(a) = V·ηρ√(A₁+A₂) + Σ_k a_k Q_k (e_com_k(B*) + e_cmp_k)
  (the −Σ Q_k E_add constant is dropped, §V-A), inner bandwidth by the KKT
  solver, outer search by the immune algorithm.
* Baselines from §VI: Random, Round-Robin (equal bandwidth), Selection [26]
  (fixed ratios per modality-combination, picked by model distance), and
  Dropout [28] (random scheduling + modality dropout on multimodal clients —
  the dropout itself is applied by the FL client, flagged here).

All schedulers return ``ScheduleDecision`` with the participation vector, the
bandwidth allocation and per-client modality-dropout flags.  Clients whose
latency constraint ends up violated (possible under the naive equal-bandwidth
baselines) are marked as transmission failures by the runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .bandwidth import allocate
from .cost import ClientCost, com_energy, com_latency
from .params import WirelessParams
from ..core.convergence import BoundState


@dataclasses.dataclass
class ScheduleContext:
    h: np.ndarray                       # channel gains this round
    Q: np.ndarray                       # Lyapunov queues
    cost: ClientCost
    params: WirelessParams
    bound: Optional[BoundState]
    round_idx: int
    model_dist: Optional[np.ndarray] = None   # ||θ_k − θ⁰|| for Selection
    client_modalities: Optional[Sequence[Sequence[str]]] = None


@dataclasses.dataclass
class ScheduleDecision:
    a: np.ndarray                       # bool [K]
    B: np.ndarray                       # [K] Hz
    dropout_modality: Optional[List[Optional[str]]] = None
    objective: float = np.nan


def _equal_bandwidth(a: np.ndarray, params: WirelessParams) -> np.ndarray:
    B = np.zeros(len(a))
    n = int(a.sum())
    if n:
        B[a] = params.B_max / n
    return B


class Scheduler:
    name = "base"

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:  # pragma: no cover
        raise NotImplementedError


class RandomScheduler(Scheduler):
    """Random client subset, equal bandwidth split."""
    name = "random"

    def __init__(self, rng: np.random.Generator, n_sched: int = 4):
        self.rng = rng
        self.n_sched = n_sched

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        K = len(ctx.h)
        a = np.zeros(K, bool)
        a[self.rng.choice(K, size=min(self.n_sched, K), replace=False)] = True
        return ScheduleDecision(a, _equal_bandwidth(a, ctx.params))


class RoundRobinScheduler(Scheduler):
    """Cycle through clients in fixed order, equal bandwidth."""
    name = "round_robin"

    def __init__(self, n_sched: int = 4):
        self.n_sched = n_sched
        self._next = 0

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        K = len(ctx.h)
        a = np.zeros(K, bool)
        for i in range(min(self.n_sched, K)):
            a[(self._next + i) % K] = True
        self._next = (self._next + self.n_sched) % K
        return ScheduleDecision(a, _equal_bandwidth(a, ctx.params))


class SelectionScheduler(Scheduler):
    """[26]: fixed selection ratio per modality-combination group; within each
    group pick the clients whose local model moved farthest from θ⁰."""
    name = "selection"

    def __init__(self, rng: np.random.Generator, ratio: float = 0.4):
        self.rng = rng
        self.ratio = ratio

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        K = len(ctx.h)
        mods = ctx.client_modalities or [("m",)] * K
        groups: Dict[frozenset, List[int]] = {}
        for k in range(K):
            groups.setdefault(frozenset(mods[k]), []).append(k)
        a = np.zeros(K, bool)
        dist = ctx.model_dist if ctx.model_dist is not None else np.zeros(K)
        for g in groups.values():
            n_pick = max(1, int(round(self.ratio * len(g))))
            order = sorted(g, key=lambda k: -dist[k])
            for k in order[:n_pick]:
                a[k] = True
        return ScheduleDecision(a, _equal_bandwidth(a, ctx.params))


class DropoutScheduler(Scheduler):
    """[28]: random scheduling; multimodal clients drop one modality w.p. p."""
    name = "dropout"

    def __init__(self, rng: np.random.Generator, n_sched: int = 4,
                 p_drop: float = 0.3):
        self.rng = rng
        self.n_sched = n_sched
        self.p_drop = p_drop

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        K = len(ctx.h)
        a = np.zeros(K, bool)
        a[self.rng.choice(K, size=min(self.n_sched, K), replace=False)] = True
        drops: List[Optional[str]] = [None] * K
        mods = ctx.client_modalities or [()] * K
        for k in range(K):
            if a[k] and len(mods[k]) > 1 and self.rng.random() < self.p_drop:
                drops[k] = str(self.rng.choice(sorted(mods[k])))
        return ScheduleDecision(a, _equal_bandwidth(a, ctx.params), drops)


class JCSBAScheduler(Scheduler):
    """The paper's joint client-scheduling + bandwidth-allocation algorithm.

    Three interchangeable solver backends (``solver=``):

    * ``"jax"`` (default) — the population-batched fused program in
      ``wireless.solver.jaxsolver``: one jitted call evaluates the whole
      immune population (KKT bandwidth bisection + Theorem-1 bound + energy
      term) per generation;
    * ``"np"`` — the float64 numpy mirror (``wireless.solver.ref``), same
      algorithm on the same random bits — the parity reference;
    * ``"seq"`` — the original sequential memoised path (scalar
      ``bandwidth.allocate`` inside ``immune.immune_search``), kept as the
      baseline the batched solver is benchmarked against.

    Warm-start seeding is explicit for every backend: the previous round's
    winner (when one exists) and the all-zeros antibody are written over the
    first population rows, so an empty schedule is always evaluated and the
    returned objective is always finite.
    """
    name = "jcsba"

    def __init__(self, rng: np.random.Generator, V: float = 1.0,
                 immune_kwargs: Optional[dict] = None, solver: str = "jax"):
        if solver not in ("jax", "np", "seq"):
            raise ValueError(f"unknown JCSBA solver backend {solver!r}")
        self.rng = rng
        self.V = V
        self.immune_kwargs = immune_kwargs or {}
        self.solver = solver
        self._last_a: Optional[np.ndarray] = None

    # -- inner: bandwidth for a candidate a; returns (B, J2) or (None, inf) --
    def _evaluate(self, a: np.ndarray, ctx: ScheduleContext):
        K = len(ctx.h)
        part = np.flatnonzero(a)
        bound_term = (ctx.bound.objective(a.astype(float))
                      if ctx.bound is not None else 0.0)
        if len(part) == 0:
            return np.zeros(K), self.V * bound_term
        tau_rem = ctx.cost.tau_residual(ctx.params)[part]
        Bp = allocate(ctx.Q[part], ctx.cost.gamma_bits[part], ctx.h[part],
                      tau_rem, ctx.params)
        if Bp is None:
            return None, np.inf
        B = np.zeros(K)
        B[part] = Bp
        tcom = com_latency(B[part], ctx.h[part], ctx.cost.gamma_bits[part],
                           ctx.params)
        ecom = com_energy(tcom, ctx.params)
        J2 = (self.V * bound_term
              + float((ctx.Q[part] * (ecom + ctx.cost.e_cmp[part])).sum()))
        return B, J2

    def _seed_antibodies(self, K: int) -> np.ndarray:
        """Warm-start rows: last round's winner (when one exists) followed by
        the all-zeros antibody — the latter is always present, so the empty
        schedule is always in the evaluated population.  1 row on round 0,
        2 afterwards (the batched backends pad to their fixed [2, K] shape)."""
        rows = [] if self._last_a is None else [np.asarray(self._last_a, bool)]
        rows.append(np.zeros(K, bool))
        return np.stack(rows)

    def _schedule_seq(self, ctx: ScheduleContext) -> ScheduleDecision:
        """Original sequential path: scalar KKT solve per memoised antibody."""
        from .immune import immune_search
        K = len(ctx.h)

        def eval_fn(a):
            _, J = self._evaluate(np.asarray(a, bool), ctx)
            return J

        a_star, J_star = immune_search(
            eval_fn, K, self.rng, seed_antibodies=self._seed_antibodies(K),
            **self.immune_kwargs)
        B, _ = self._evaluate(a_star, ctx)
        if B is None:                                   # paranoid fallback
            a_star = np.zeros(K, bool)
            B = np.zeros(K)
        self._last_a = a_star.copy()
        return ScheduleDecision(a_star, B, objective=J_star)

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        if self.solver == "seq":
            return self._schedule_seq(ctx)
        from .solver import (SolverHyper, build_solver_data, solve_round,
                             solve_round_np)
        K = len(ctx.h)
        hp = SolverHyper(**self.immune_kwargs)
        data = build_solver_data(ctx.h, ctx.Q, ctx.cost, ctx.params,
                                 ctx.bound, self.V)
        seeds = self._seed_antibodies(K)
        if len(seeds) < 2:      # fixed [2, K] shape keeps the jit cache warm
            seeds = np.vstack([seeds, np.zeros((2 - len(seeds), K), bool)])
        # both backends consume the same jax.random bits from this seed, so
        # solver="jax" and solver="np" walk the same search trajectory
        draw_seed = int(self.rng.integers(2 ** 31))
        solve = solve_round if self.solver == "jax" else solve_round_np
        a_star, J_star, B = solve(data, seeds, draw_seed, hp)
        a_star = np.asarray(a_star, bool)
        self._last_a = a_star.copy()
        return ScheduleDecision(a_star, np.asarray(B, float),
                                objective=float(J_star))


def make_scheduler(name: str, rng: np.random.Generator, **kw) -> Scheduler:
    name = name.lower()
    if name == "random":
        return RandomScheduler(rng, **kw)
    if name in ("round_robin", "roundrobin"):
        return RoundRobinScheduler(**kw)
    if name == "selection":
        return SelectionScheduler(rng, **kw)
    if name == "dropout":
        return DropoutScheduler(rng, **kw)
    if name == "jcsba":
        return JCSBAScheduler(rng, **kw)
    raise ValueError(f"unknown scheduler {name!r}")
