"""Client scheduling strategies — thin host wrappers over traced policies.

* ``JCSBAScheduler`` — the paper's algorithm: per-round P3 objective
  J₂(a) = V·ηρ√(A₁+A₂) + Σ_k a_k Q_k (e_com_k(B*) + e_cmp_k)
  (the −Σ Q_k E_add constant is dropped, §V-A), inner bandwidth by the KKT
  solver, outer search by the immune algorithm.
* Baselines from §VI: Random, Round-Robin (equal bandwidth), Selection [26]
  (fixed ratios per modality-combination, picked by model distance), and
  Dropout [28] (random scheduling + modality dropout on multimodal clients —
  the dropout itself is applied by the FL client, flagged here).

Every policy's decision logic lives in ``wireless.policies`` as a pure
jittable ``SchedulePolicy.step``; the ``Scheduler`` classes here only manage
host state (rng stream, policy state, ScheduleContext → device conversion)
and jit the same traced core the fused round engine inlines — so the host
loop and ``MFLExperiment(engine="fused")`` agree by construction.

RNG discipline: every policy-backed scheduler — Dropout included since its
drop draws moved into the traced ``DropoutPolicy`` core — consumes exactly
ONE ``rng.integers(2**31)`` host draw per round (the seed of the round's
``jax.random`` key), scheduled or not, feasible or not — the same static
pattern ``fl.fused_round.draw_round_xs`` pregenerates.  Only JCSBA's np/seq
parity backends remain host-side.

Policy state (JCSBA's warm-start antibody, Round-Robin's cursor) is exposed
through ``state()/load_state()`` — the checkpointing API the runtime uses
instead of reaching into scheduler internals.

All schedulers return ``ScheduleDecision`` with the participation vector, the
bandwidth allocation and per-client modality-dropout flags.  Clients whose
latency constraint ends up violated (possible under the naive equal-bandwidth
baselines) are marked as transmission failures by the runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .bandwidth import allocate
from .cost import ClientCost, com_energy, com_latency
from .params import WirelessParams
from ..core.convergence import BoundState


@dataclasses.dataclass
class ScheduleContext:
    h: np.ndarray                       # channel gains this round
    Q: np.ndarray                       # Lyapunov queues
    cost: ClientCost
    params: WirelessParams
    bound: Optional[BoundState]
    round_idx: int
    model_dist: Optional[np.ndarray] = None   # ||θ_k − θ⁰|| for Selection
    client_modalities: Optional[Sequence[Sequence[str]]] = None


@dataclasses.dataclass
class ScheduleDecision:
    a: np.ndarray                       # bool [K]
    B: np.ndarray                       # [K] Hz
    dropout_modality: Optional[List[Optional[str]]] = None
    objective: float = np.nan


class Scheduler:
    name = "base"
    policy = None           # traced core, when one exists (PolicyScheduler)

    def bind(self, K: int,
             client_modalities: Optional[Sequence] = None) -> None:
        """Bind the scheduler to a cohort size (no-op for host-only
        schedulers).  Policy-backed schedulers build their traced core here;
        the runtime calls this at experiment init so ``policy``/``state()``
        are live before the first round."""

    def state(self) -> Dict[str, np.ndarray]:
        """Checkpointable policy state (empty for stateless schedulers)."""
        return {}

    def load_state(self, state: Dict[str, np.ndarray]) -> None:
        """Restore ``state()``'s dict (no-op for stateless schedulers)."""

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:  # pragma: no cover
        raise NotImplementedError


class PolicyScheduler(Scheduler):
    """Host wrapper over a traced ``wireless.policies.SchedulePolicy``.

    Subclasses implement ``_make_policy(K, client_modalities)`` and, when the
    policy needs more context than ``B_max`` (JCSBA), ``_build_data(ctx)``.
    ``schedule`` drives the shared jitted ``policies.policy_step`` and keeps
    the policy state as host numpy between rounds.
    """

    def __init__(self, rng: np.random.Generator):
        self.rng = rng
        self._policy = None
        self._state: Optional[Dict[str, np.ndarray]] = None
        self._K: Optional[int] = None

    # -- traced-core lifecycle ------------------------------------------
    def _make_policy(self, K: int, client_modalities):
        raise NotImplementedError

    def bind(self, K: int, client_modalities=None) -> None:
        self._K = K
        pol = self._make_policy(K, client_modalities)
        if pol is None:                     # host-only backend (jcsba np/seq)
            self._policy = None
            return
        # policies are frozen dataclasses, so value equality detects any
        # config change (K, n_sched, Selection's group structure, ...);
        # an unchanged policy keeps its evolving state across rebinds
        if pol != self._policy:
            self._policy = pol
            self._state = pol.init_state()

    @property
    def policy(self):
        return self._policy

    # -- checkpoint API --------------------------------------------------
    def state(self) -> Dict[str, np.ndarray]:
        return {} if self._state is None else \
            {k: np.asarray(v) for k, v in self._state.items()}

    def load_state(self, state: Dict[str, np.ndarray]) -> None:
        if self._state is None:
            self._state = {}
        for k, tmpl in list(self._state.items()):
            if k in state:
                self._state[k] = np.asarray(state[k], tmpl.dtype)

    # -- the per-round drive ---------------------------------------------
    def _build_data(self, ctx: ScheduleContext) -> dict:
        import jax.numpy as jnp
        return {"B_max": jnp.float32(ctx.params.B_max)}

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        import jax.numpy as jnp
        from .policies import policy_step
        K = len(ctx.h)
        self.bind(K, ctx.client_modalities)
        draw_seed = np.uint32(self.rng.integers(2 ** 31))
        dist = (np.zeros(K) if ctx.model_dist is None else ctx.model_dist)
        state = {k: jnp.asarray(v) for k, v in self._state.items()}
        state, a, B, J, drop, _ = policy_step(self._policy, state,
                                              self._build_data(ctx),
                                              jnp.asarray(dist, jnp.float32),
                                              draw_seed)
        self._state = {k: np.asarray(v) for k, v in state.items()}
        # decode the traced drop mask (row order = policy.drop_mods) into the
        # per-client dropout_modality list the FL runtime consumes
        drops: Optional[List[Optional[str]]] = None
        drop = np.asarray(drop, bool)
        if drop.shape[0]:
            drops = [None] * K
            for i, m in enumerate(self._policy.drop_mods):
                for k in np.flatnonzero(drop[i]):
                    drops[k] = m
        return ScheduleDecision(np.asarray(a, bool),
                                np.asarray(B, np.float64),
                                dropout_modality=drops,
                                objective=float(J))


class RandomScheduler(PolicyScheduler):
    """Random client subset, equal bandwidth split."""
    name = "random"

    def __init__(self, rng: np.random.Generator, n_sched: int = 4):
        super().__init__(rng)
        self.n_sched = n_sched

    def _make_policy(self, K, client_modalities):
        from .policies import make_policy
        return make_policy(self.name, K, n_sched=self.n_sched)


class RoundRobinScheduler(PolicyScheduler):
    """Cycle through clients in fixed order, equal bandwidth."""
    name = "round_robin"

    def __init__(self, rng: np.random.Generator, n_sched: int = 4):
        super().__init__(rng)
        self.n_sched = n_sched

    def _make_policy(self, K, client_modalities):
        from .policies import make_policy
        return make_policy(self.name, K, n_sched=self.n_sched)


class SelectionScheduler(PolicyScheduler):
    """[26]: fixed selection ratio per modality-combination group; within each
    group pick the clients whose local model moved farthest from θ⁰."""
    name = "selection"

    def __init__(self, rng: np.random.Generator, ratio: float = 0.4):
        super().__init__(rng)
        self.ratio = ratio

    def _make_policy(self, K, client_modalities):
        from .policies import make_policy
        return make_policy(self.name, K, client_modalities,
                           ratio=self.ratio)


class DropoutScheduler(PolicyScheduler):
    """[28]: random scheduling; multimodal clients drop one modality w.p. p.

    Formerly the one host-only baseline (its per-client drop draws were
    data-dependent host rng); the draws now live in the traced
    ``policies.DropoutPolicy`` core — pregenerated from the single round key,
    one pair of uniforms per client — so Dropout schedules (and drops)
    identically under the host loop and the fused engine."""
    name = "dropout"

    def __init__(self, rng: np.random.Generator, n_sched: int = 4,
                 p_drop: float = 0.3):
        super().__init__(rng)
        self.n_sched = n_sched
        self.p_drop = p_drop

    def _make_policy(self, K, client_modalities):
        from .policies import make_policy
        return make_policy(self.name, K, client_modalities,
                           n_sched=self.n_sched, p_drop=self.p_drop)


class JCSBAScheduler(PolicyScheduler):
    """The paper's joint client-scheduling + bandwidth-allocation algorithm.

    Three interchangeable solver backends (``solver=``):

    * ``"jax"`` (default) — the traced ``policies.JCSBAPolicy`` core over the
      population-batched fused program in ``wireless.solver.jaxsolver``: one
      jitted call evaluates the whole immune population (KKT bandwidth
      bisection + Theorem-1 bound + energy term) per generation;
    * ``"np"`` — the float64 numpy mirror (``wireless.solver.ref``), same
      algorithm on the same random bits — the parity reference;
    * ``"seq"`` — the original sequential memoised path (scalar
      ``bandwidth.allocate`` inside ``immune.immune_search``), kept as the
      baseline the batched solver is benchmarked against.

    Warm-start seeding is explicit for every backend: the previous round's
    winner (when one exists) and the all-zeros antibody are written over the
    first population rows, so an empty schedule is always evaluated and the
    returned objective is always finite.  For ``solver="jax"`` the warm start
    IS the policy state (``state()["warm_a"]``); the np/seq backends keep it
    in ``_last_a`` (an all-zeros warm row is indistinguishable from "no
    winner yet" after seed padding, so the two representations round-trip
    exactly through ``state()/load_state()``).
    """
    name = "jcsba"

    def __init__(self, rng: np.random.Generator, V: float = 1.0,
                 immune_kwargs: Optional[dict] = None, solver: str = "jax"):
        if solver not in ("jax", "np", "seq"):
            raise ValueError(f"unknown JCSBA solver backend {solver!r}")
        super().__init__(rng)
        self.V = V
        self.immune_kwargs = immune_kwargs or {}
        self.solver = solver
        self._last_a: Optional[np.ndarray] = None    # np/seq warm start

    def _make_policy(self, K, client_modalities):
        if self.solver != "jax":
            return None
        from .policies import make_policy
        return make_policy(self.name, K, immune_kwargs=self.immune_kwargs)

    def _build_data(self, ctx: ScheduleContext) -> dict:
        from .solver import build_solver_data
        from .solver.jaxsolver import to_device
        return to_device(build_solver_data(ctx.h, ctx.Q, ctx.cost,
                                           ctx.params, ctx.bound, self.V))

    # -- checkpoint API covers all three backends ------------------------
    def state(self) -> Dict[str, np.ndarray]:
        if self.solver == "jax" and self._state is not None:
            return {k: np.asarray(v) for k, v in self._state.items()}
        K = self._K if self._K is not None else (
            len(self._last_a) if self._last_a is not None else 0)
        warm = (np.zeros(K, bool) if self._last_a is None
                else np.asarray(self._last_a, bool))
        return {"warm_a": warm}

    def load_state(self, state: Dict[str, np.ndarray]) -> None:
        if "warm_a" not in state:
            return
        warm = np.asarray(state["warm_a"], bool)
        if self.solver == "jax":
            self.bind(len(warm))
            self._state = {"warm_a": warm}
        else:
            self._last_a = warm

    # -- inner: bandwidth for a candidate a; returns (B, J2) or (None, inf) --
    def _evaluate(self, a: np.ndarray, ctx: ScheduleContext):
        K = len(ctx.h)
        part = np.flatnonzero(a)
        bound_term = (ctx.bound.objective(a.astype(float))
                      if ctx.bound is not None else 0.0)
        if len(part) == 0:
            return np.zeros(K), self.V * bound_term
        tau_rem = ctx.cost.tau_residual(ctx.params)[part]
        Bp = allocate(ctx.Q[part], ctx.cost.gamma_bits[part], ctx.h[part],
                      tau_rem, ctx.params)
        if Bp is None:
            return None, np.inf
        B = np.zeros(K)
        B[part] = Bp
        tcom = com_latency(B[part], ctx.h[part], ctx.cost.gamma_bits[part],
                           ctx.params)
        ecom = com_energy(tcom, ctx.params)
        J2 = (self.V * bound_term
              + float((ctx.Q[part] * (ecom + ctx.cost.e_cmp[part])).sum()))
        return B, J2

    def _seed_antibodies(self, K: int) -> np.ndarray:
        """Warm-start rows: last round's winner (when one exists) followed by
        the all-zeros antibody — the latter is always present, so the empty
        schedule is always in the evaluated population.  1 row on round 0,
        2 afterwards (the batched backends pad to their fixed [2, K] shape)."""
        rows = [] if self._last_a is None else [np.asarray(self._last_a, bool)]
        rows.append(np.zeros(K, bool))
        return np.stack(rows)

    def _schedule_seq(self, ctx: ScheduleContext) -> ScheduleDecision:
        """Original sequential path: scalar KKT solve per memoised antibody."""
        from .immune import immune_search
        K = len(ctx.h)

        def eval_fn(a):
            _, J = self._evaluate(np.asarray(a, bool), ctx)
            return J

        a_star, J_star = immune_search(
            eval_fn, K, self.rng, seed_antibodies=self._seed_antibodies(K),
            **self.immune_kwargs)
        B, _ = self._evaluate(a_star, ctx)
        if B is None:                                   # paranoid fallback
            a_star = np.zeros(K, bool)
            B = np.zeros(K)
        self._last_a = a_star.copy()
        return ScheduleDecision(a_star, B, objective=J_star)

    def _schedule_np(self, ctx: ScheduleContext) -> ScheduleDecision:
        """Float64 numpy mirror on the identical jax.random bits."""
        from .solver import SolverHyper, build_solver_data, solve_round_np
        K = len(ctx.h)
        hp = SolverHyper(**self.immune_kwargs)
        data = build_solver_data(ctx.h, ctx.Q, ctx.cost, ctx.params,
                                 ctx.bound, self.V)
        seeds = self._seed_antibodies(K)
        if len(seeds) < 2:      # fixed [2, K] shape matches the jax backend
            seeds = np.vstack([seeds, np.zeros((2 - len(seeds), K), bool)])
        draw_seed = int(self.rng.integers(2 ** 31))
        a_star, J_star, B = solve_round_np(data, seeds, draw_seed, hp)
        a_star = np.asarray(a_star, bool)
        self._last_a = a_star.copy()
        return ScheduleDecision(a_star, np.asarray(B, float),
                                objective=float(J_star))

    def schedule(self, ctx: ScheduleContext) -> ScheduleDecision:
        self.bind(len(ctx.h), ctx.client_modalities)
        if self.solver == "seq":
            return self._schedule_seq(ctx)
        if self.solver == "np":
            return self._schedule_np(ctx)
        return super().schedule(ctx)


def make_scheduler(name: str, rng: np.random.Generator, **kw) -> Scheduler:
    name = name.lower()
    if name == "random":
        return RandomScheduler(rng, **kw)
    if name in ("round_robin", "roundrobin"):
        return RoundRobinScheduler(rng, **kw)
    if name == "selection":
        return SelectionScheduler(rng, **kw)
    if name == "dropout":
        return DropoutScheduler(rng, **kw)
    if name == "jcsba":
        return JCSBAScheduler(rng, **kw)
    raise ValueError(f"unknown scheduler {name!r}")
