"""Optimal bandwidth allocation — the continuous subproblem P4.2' (§V-C).

P4.2': min Σ_{k∈K^t} Q_k p Γ_k / r_k(B_k)   s.t.  Σ B_k = B_max,
       r_k(B_k) ≥ Γ_k / (τ_max − τ_cmp_k)   (per-client latency, In1)

The objective is convex (Eq. 38) and the KKT conditions reduce to a
water-filling structure over the multiplier κ (Eqs. 43-48): clients whose
latency constraint binds sit at B_k^min (λ₄>0), the rest satisfy
φ_k(B_k) = κ* where φ_k = ∂J₃/∂B_k (Eq. 37, negative & increasing in B).

The paper enumerates the sorted κ intervals and runs Newton per interval; we
solve the *same* KKT system by bisection on κ* — Σ_k B_k(κ) is monotone
increasing in κ, so the bisection converges to the unique KKT point with the
same O(U log 1/ε) inner work.  Equivalence is asserted against a brute-force
projected-grid optimiser in tests/test_bandwidth.py.

This module is the scalar *sequential* reference (one candidate schedule per
call, adaptive-termination loops, ``None`` for infeasibility).  The hot path
used by ``schedulers.JCSBAScheduler`` is the population-batched twin in
``wireless/solver/`` — fixed-iteration bisections vmapped over whole antibody
populations, with infeasibility returned as a mask; cross-equivalence against
this module is asserted in tests/test_solver_parity.py.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from .params import WirelessParams
from .channel import rate_ceiling

_TOL_B = 1.0          # [Hz] absolute bandwidth tolerance
_MAX_IT = 200


def _rate(B: float, h: float, p: WirelessParams) -> float:
    if B <= 0:
        return 0.0
    return B * np.log1p(p.p_tx * h / (B * p.N0)) / np.log(2.0)


def phi(B: float, Q: float, gamma: float, h: float, p: WirelessParams) -> float:
    """φ = ∂J₃/∂B (Eq. 37). Negative, strictly increasing in B, → 0⁻."""
    x = p.p_tx * h / (B * p.N0)
    ln1x = np.log1p(x)
    num = x / (1.0 + x) - ln1x
    return Q * p.p_tx * gamma * np.log(2.0) * num / (B * B * ln1x * ln1x)


def b_min(gamma: float, h: float, tau_rem: float,
          p: WirelessParams) -> Optional[float]:
    """Unique B with r(B) = Γ/τ_rem (Eq. 41); None if infeasible."""
    if tau_rem <= 0:
        return None
    target = gamma / tau_rem
    if target >= rate_ceiling(np.array([h]), p)[0] * (1 - 1e-12):
        return None                       # even infinite bandwidth can't do it
    lo, hi = 1e-3, 1e4
    while _rate(hi, h, p) < target:
        hi *= 4.0
        if hi > 1e16:
            return None
    for _ in range(_MAX_IT):
        mid = 0.5 * (lo + hi)
        if _rate(mid, h, p) < target:
            lo = mid
        else:
            hi = mid
        if hi - lo < _TOL_B * 1e-3:
            break
    return hi


def _phi_inv(kappa: float, bmin_k: float, Q: float, gamma: float, h: float,
             p: WirelessParams) -> float:
    """B ≥ B_min with φ(B) = κ; clamps to B_min when φ(B_min) ≥ κ (E1/E2)."""
    if phi(bmin_k, Q, gamma, h, p) >= kappa:
        return bmin_k
    lo, hi = bmin_k, max(2 * bmin_k, 1e4)
    while phi(hi, Q, gamma, h, p) < kappa:
        hi *= 4.0
        if hi > 1e18:
            return hi
    for _ in range(_MAX_IT):
        mid = 0.5 * (lo + hi)
        if phi(mid, Q, gamma, h, p) < kappa:
            lo = mid
        else:
            hi = mid
        if hi - lo < _TOL_B * 1e-3:
            break
    return 0.5 * (lo + hi)


def allocate(Q: np.ndarray, gamma: np.ndarray, h: np.ndarray,
             tau_rem: np.ndarray, p: WirelessParams) -> Optional[np.ndarray]:
    """Solve P4.2' for the participating clients.

    All arrays are over the participant set K^t.  Returns B* (same length) or
    None if the participation vector is infeasible (Eq. 42 violated).
    """
    U = len(Q)
    if U == 0:
        return np.zeros(0)
    bmins = np.empty(U)
    for i in range(U):
        b = b_min(gamma[i], h[i], tau_rem[i], p)
        if b is None:
            return None
        bmins[i] = b
    total_min = bmins.sum()
    if total_min > p.B_max + _TOL_B:
        return None                                   # (42) unsatisfied
    if total_min >= p.B_max - _TOL_B:
        return bmins                                  # (42) holds with equality
    # Q=0 clients have φ ≡ 0 ≥ κ for any κ<0: they stay at B_min.  If every
    # participant has Q=0 the objective is flat — split the slack evenly.
    if np.all(Q <= 0):
        return bmins + (p.B_max - total_min) / U

    def total(kappa: float) -> float:
        return sum(_phi_inv(kappa, bmins[i], Q[i], gamma[i], h[i], p)
                   for i in range(U))

    k_lo = min(phi(bmins[i], Q[i], gamma[i], h[i], p)
               for i in range(U) if Q[i] > 0)
    k_hi = -1e-300
    for _ in range(_MAX_IT):
        k_mid = 0.5 * (k_lo + k_hi) if k_hi < 0 else k_lo / 2
        t = total(k_mid)
        if t < p.B_max:
            k_lo = k_mid
        else:
            k_hi = k_mid
        if abs(t - p.B_max) < _TOL_B:
            break
    B = np.array([_phi_inv(k_hi, bmins[i], Q[i], gamma[i], h[i], p)
                  for i in range(U)])
    # distribute any residual rounding slack proportionally (keeps Σ=B_max)
    slack = p.B_max - B.sum()
    free = B > bmins + _TOL_B
    if slack != 0 and free.any():
        B[free] += slack / free.sum()
    elif slack != 0:
        B += slack / U
    return np.maximum(B, bmins)
