"""Immune algorithm for the combinatorial scheduling subproblem P4.1
(Algorithm 2 of the paper).

Antibody = participation vector a ∈ {0,1}^K.  Affinity derives from J₂(a)
(Eq. 50, infeasible → 0); concentration (Eq. 51-52) uses the Hamming-distance
similarity threshold Dis; the incentive (Eq. 53) trades affinity against
concentration to preserve diversity.  Default hyper-parameters follow
Algorithm 2's header: S=20, G=10, μ=5, z=0.175.

The paper returns the best antibody of the final generation; we additionally
keep the best *feasible* antibody seen across generations (never worse).
Objective evaluations are memoised — the bandwidth KKT solve dominates the
cost, and clones repeat genotypes frequently.

This is the *sequential* reference (one eval_fn call per antibody), kept as
the ``solver="seq"`` backend of ``schedulers.JCSBAScheduler``.  The default
path is the population-batched rewrite in ``wireless/solver/`` — clone/
mutate/select on a [P, K] population array with ``jax.random`` draws, every
generation one fused jitted evaluation.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import numpy as np


def immune_search(eval_fn: Callable[[np.ndarray], float],
                  K: int,
                  rng: np.random.Generator,
                  S: int = 20, G: int = 10, mu: int = 5, z: float = 0.175,
                  iota: float = 4.0, dis: int = 2,
                  eps1: float = 1.0, eps2: float = 0.15,
                  seed_antibodies: Optional[np.ndarray] = None,
                  ) -> Tuple[np.ndarray, float]:
    """Minimise eval_fn(a) (np.inf = infeasible). Returns (a*, J*)."""
    memo: Dict[bytes, float] = {}

    def J(a: np.ndarray) -> float:
        key = np.packbits(a).tobytes()
        if key not in memo:
            memo[key] = float(eval_fn(a))
        return memo[key]

    pop = rng.integers(0, 2, (S, K)).astype(bool)
    if seed_antibodies is not None:
        n = min(len(seed_antibodies), S)
        pop[:n] = seed_antibodies[:n]

    best_a, best_J = None, np.inf
    n_elite = max(S // mu, 1)
    n_keep = S - n_elite

    def affinity(vals: np.ndarray) -> np.ndarray:
        finite = np.isfinite(vals)
        if not finite.any():
            return np.zeros_like(vals)
        jmax, jmin = vals[finite].max(), vals[finite].min()
        span = max(jmax - jmin, 1e-12)
        aff = np.where(finite, ((jmax - vals) / span + 1e-6) ** iota, 0.0)
        return aff

    for g in range(G):
        vals = np.array([J(a) for a in pop])
        imin = int(np.argmin(vals))
        if vals[imin] < best_J:
            best_J, best_a = vals[imin], pop[imin].copy()

        aff = affinity(vals)
        ham = (pop[:, None, :] != pop[None, :, :]).sum(-1)
        con = (ham <= dis).mean(axis=1)                       # Eq. 51-52
        inc = eps1 * aff - eps2 * con                         # Eq. 53

        elite_idx = np.argsort(-inc)[:n_elite]
        elites = pop[elite_idx]
        clones = np.repeat(elites, mu, axis=0)                # μ-fold cloning
        mut = rng.random(clones.shape) < z
        mutants = clones ^ mut
        cand = np.concatenate([mutants, elites], axis=0)
        cand_vals = np.array([J(a) for a in cand])
        cand_aff = affinity(cand_vals)
        keep = cand[np.argsort(-cand_aff)[:n_keep]]
        fresh = rng.integers(0, 2, (S - n_keep, K)).astype(bool)
        pop = np.concatenate([keep, fresh], axis=0)

    # final generation check
    vals = np.array([J(a) for a in pop])
    imin = int(np.argmin(vals))
    if vals[imin] < best_J:
        best_J, best_a = vals[imin], pop[imin].copy()
    if best_a is None:                                        # all infeasible
        best_a = np.zeros(K, bool)
        best_J = J(best_a)
    return best_a, best_J
