"""Traced scheduling policies — every scheduler's per-round decision as one
pure jittable program.

The paper's evaluation (Figs. 4-6, Table 3) compares JCSBA against Random /
Round-Robin / Selection baselines.  Historically only JCSBA had a traced core
(``wireless.solver``); the baselines were host-side numpy loops, which locked
the fused round engine (fl/fused_round.py) to ``scheduler="jcsba"``.  This
module makes *every* policy a :class:`SchedulePolicy`: a frozen (hashable,
jit-static) object exposing

* ``init_state()`` — the policy's evolving state as a dict-of-arrays pytree
  (JCSBA: the warm-start antibody; Round-Robin: the cursor; Random /
  Selection: empty), carried through ``lax.scan`` by the fused engine and
  checkpointed via the schedulers' ``state()/load_state()`` API;
* ``step(state, data, model_dist, key)`` — one round's decision
  ``(new_state, a, B, J)`` as a pure traced function of the round context
  ``data`` (the ``solver.common.build_solver_data`` dict, f32 on device),
  the ‖θ_k − θ⁰‖ bookkeeping and a ``jax.random`` key derived from the
  round's single host seed draw.

The host-side ``Scheduler`` classes in ``schedulers.py`` are thin wrappers
that jit the *same* ``step`` — host/fused parity is by construction, not by
mirroring (tests/test_fused_round.py locks it per policy).  Random bits come
exclusively from the per-round ``key`` (one ``rng.integers(2**31)`` host draw
per round for every policy — the static rng discipline PR 3 established for
JCSBA), so fused xs pregeneration stays draw-for-draw identical to the host
loop for all policies.

Policies whose decision includes *modality dropout* ([28]'s baseline) emit a
per-modality drop mask as a fifth output of ``step_full`` — see
:class:`DropoutPolicy`.  Policies without dropout inherit the default
zero-row mask, so the fused engine consumes one uniform decision shape.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .solver import SolverHyper
from .solver.jaxsolver import solve_core

POLICY_NAMES = ("jcsba", "random", "round_robin", "selection", "dropout")


def equal_bandwidth_traced(a, B_max):
    """Traced twin of the baselines' equal split: B_max/n over scheduled
    clients, exact zeros elsewhere (and everywhere when nobody is scheduled).
    """
    n = a.sum()
    share = jnp.asarray(B_max, jnp.float32) / jnp.maximum(n, 1)
    return jnp.where(a, share, jnp.float32(0.0))


class SchedulePolicy:
    """Protocol for traced per-round scheduling decisions.

    Implementations must be immutable/hashable (frozen dataclasses) so they
    can ride along as static jit arguments; all evolving state flows through
    ``state``.  ``data`` is the round-context dict of
    ``solver.common.build_solver_data`` — policies read only the keys they
    need (baselines: ``B_max``; JCSBA: the full solver context).
    """
    name = "base"
    #: modality names addressing ``step_full``'s drop-mask rows (empty for
    #: policies without dropout)
    drop_mods: Tuple[str, ...] = ()

    def init_state(self) -> Dict[str, np.ndarray]:
        return {}

    def step(self, state, data, model_dist, key):
        """-> (new_state, a [K] bool, B [K] f32, J scalar f32)."""
        raise NotImplementedError

    def step_full(self, state, data, model_dist, key):
        """-> (new_state, a, B, J, drop [M_drop, K] bool) — the full decision
        including per-modality drop masks in ``self.drop_mods`` row order.
        Policies without dropout emit the zero-row mask (M_drop = 0), so the
        consumer can branch on the *static* row count at trace time."""
        new_state, a, B, J = self.step(state, data, model_dist, key)
        return new_state, a, B, J, jnp.zeros((0, a.shape[0]), bool)


@dataclasses.dataclass(frozen=True)
class JCSBAPolicy(SchedulePolicy):
    """The paper's joint scheduling + bandwidth algorithm (Algorithm 2 +
    P4.2' + Theorem-1 bound) via the population-batched fused solver.  State
    is the warm-start antibody: the previous round's winner is written over
    population row 0, the all-zeros antibody over row 1 (so the empty
    schedule is always evaluated and J* is always finite)."""
    K: int
    hp: SolverHyper = SolverHyper()
    name = "jcsba"

    def init_state(self):
        return {"warm_a": np.zeros(self.K, bool)}

    def step(self, state, data, model_dist, key):
        warm = jnp.asarray(state["warm_a"], bool)
        seeds = jnp.stack([warm, jnp.zeros_like(warm)])
        a, J, B = solve_core(data, seeds, key, self.hp)
        return {"warm_a": a}, a, B, J


@dataclasses.dataclass(frozen=True)
class RandomPolicy(SchedulePolicy):
    """Random client subset (without replacement), equal bandwidth split."""
    K: int
    n_sched: int = 4
    name = "random"

    def step(self, state, data, model_dist, key):
        n = min(self.n_sched, self.K)
        perm = jax.random.permutation(key, self.K)
        a = jnp.zeros(self.K, bool).at[perm[:n]].set(True)
        return state, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan)


@dataclasses.dataclass(frozen=True)
class RoundRobinPolicy(SchedulePolicy):
    """Cycle through clients in fixed order, equal bandwidth.  State is the
    cursor (int32), which now checkpoints/restores with the experiment."""
    K: int
    n_sched: int = 4
    name = "round_robin"

    def init_state(self):
        return {"next": np.zeros((), np.int32)}

    def step(self, state, data, model_dist, key):
        n = min(self.n_sched, self.K)
        idx = (state["next"] + jnp.arange(n, dtype=jnp.int32)) % self.K
        a = jnp.zeros(self.K, bool).at[idx].set(True)
        new = {"next": (state["next"] + jnp.int32(self.n_sched)) % self.K}
        return new, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan)


@dataclasses.dataclass(frozen=True)
class SelectionPolicy(SchedulePolicy):
    """[26]: fixed selection ratio per modality-combination group; within
    each group pick the clients whose local model moved farthest from θ⁰.

    Group structure is static (derived from the cohort's modality ownership
    at build time): ``group_ids[k]`` is client k's group, ``group_picks``
    holds ``(group, n_pick)`` with ``n_pick = max(1, round(ratio·|group|))``.
    The per-group top-k is a stable argsort over ``model_dist`` masked to the
    group — ties resolve to the lowest client index, exactly like the old
    host loop's stable ``sorted``."""
    K: int
    group_ids: Tuple[int, ...]
    group_picks: Tuple[Tuple[int, int], ...]
    name = "selection"

    @classmethod
    def from_modalities(cls, K: int,
                        client_modalities: Optional[Sequence[Sequence[str]]],
                        ratio: float = 0.4) -> "SelectionPolicy":
        mods = client_modalities or [("m",)] * K
        gid_of: Dict[frozenset, int] = {}
        gids = [gid_of.setdefault(frozenset(m), len(gid_of)) for m in mods]
        sizes: Dict[int, int] = {}
        for g in gids:
            sizes[g] = sizes.get(g, 0) + 1
        picks = tuple(sorted((g, max(1, int(round(ratio * n))))
                             for g, n in sizes.items()))
        return cls(K, tuple(gids), picks)

    def step(self, state, data, model_dist, key):
        gid = jnp.asarray(self.group_ids, jnp.int32)
        dist = jnp.asarray(model_dist, jnp.float32)
        a = jnp.zeros(self.K, bool)
        for g, n_pick in self.group_picks:
            scores = jnp.where(gid == g, dist, -jnp.inf)
            top = jnp.argsort(-scores)[:n_pick]
            a = a.at[top].set(True)
        return state, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan)


def dropout_draws(key, K: int):
    """The dropout baseline's per-client uniforms: ``(u_drop [K], u_which
    [K])`` — drop-the-coin and which-modality draws for every client.

    Client k's pair comes from ``fold_in(key, k)``, so a draw depends on
    exactly (round key, client index): growing or shrinking the cohort never
    perturbs the bits of the clients that remain (property-tested in
    tests/test_fused_properties.py)."""
    def one(k):
        return jax.random.uniform(jax.random.fold_in(key, k), (2,))
    u = jax.vmap(one)(jnp.arange(K, dtype=jnp.uint32))
    return u[:, 0], u[:, 1]


@dataclasses.dataclass(frozen=True)
class DropoutPolicy(SchedulePolicy):
    """[28]: random scheduling + modality dropout — scheduled *multimodal*
    clients drop one uniformly-chosen owned modality with probability
    ``p_drop`` (unimodal clients never drop, so nobody is ever dropped to
    zero modalities).  The drop decision is part of the traced decision:
    ``step_full`` emits a ``[M, K]`` drop mask whose rows follow
    ``drop_mods`` (the cohort's modality names, sorted — the same order the
    old host loop's ``rng.choice(sorted(mods))`` ranked candidates).

    Ownership is static (``owns[i][k]`` ⇔ client k owns ``drop_mods[i]``),
    so which-modality draws map to mask rows by the precomputed ownership
    ranks; all randomness comes from the single round key: one split for the
    schedule subset, one ``dropout_draws`` stream for the drop bits."""
    K: int
    drop_mods: Tuple[str, ...] = ()
    owns: Tuple[Tuple[bool, ...], ...] = ()  # [M][K], static
    n_sched: int = 4
    p_drop: float = 0.3
    name = "dropout"

    @classmethod
    def from_modalities(cls, K: int,
                        client_modalities: Optional[Sequence[Sequence[str]]],
                        n_sched: int = 4, p_drop: float = 0.3
                        ) -> "DropoutPolicy":
        mods = client_modalities or [("m",)] * K
        names = tuple(sorted({m for ms in mods for m in ms}))
        owns = tuple(tuple(m in ms for ms in mods) for m in names)
        return cls(K, names, owns, n_sched, float(p_drop))

    def drop_mask(self, a, key):
        """[M, K] bool — modality ``drop_mods[i]`` dropped by client k."""
        owns = jnp.asarray(self.owns, bool)                  # [M, K]
        n_owned = owns.sum(0)                                # [K]
        u_drop, u_which = dropout_draws(key, self.K)
        do = jnp.asarray(a, bool) & (n_owned > 1) & (u_drop < self.p_drop)
        # uniform pick among the client's owned modalities, in row order:
        # rank[i, k] = #owned rows above i; the pick is the rank-th owned row
        which = jnp.minimum((u_which * n_owned).astype(jnp.int32),
                            jnp.maximum(n_owned - 1, 0))
        rank = jnp.cumsum(owns, axis=0) - owns
        return do[None] & owns & (rank == which[None])

    def step(self, state, data, model_dist, key):
        new_state, a, B, J, _ = self.step_full(state, data, model_dist, key)
        return new_state, a, B, J

    def step_full(self, state, data, model_dist, key):
        k_sub, k_drop = jax.random.split(key)
        n = min(self.n_sched, self.K)
        perm = jax.random.permutation(k_sub, self.K)
        a = jnp.zeros(self.K, bool).at[perm[:n]].set(True)
        return state, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan), self.drop_mask(a, k_drop)


# ---------------------------------------------------------------------------
# host entry point: one jitted step per (policy, pytree-signature)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames="policy")
def policy_step(policy: SchedulePolicy, state, data, model_dist, seed):
    """Jitted host-facing wrapper around ``policy.step_full``: derives the
    round's ``jax.random`` key from the scalar ``seed`` (a uint32 array, NOT
    a Python int — Python ints would retrace per round) exactly like the
    fused engine does from ``xs.draw_seed``, so both paths consume identical
    bits.  Returns the 5-tuple ``(state, a, B, J, drop)``; the drop mask has
    zero rows for policies without dropout."""
    return policy.step_full(state, data, model_dist, jax.random.PRNGKey(seed))


def make_policy(name: str, K: int,
                client_modalities: Optional[Sequence[Sequence[str]]] = None,
                **kw) -> SchedulePolicy:
    name = name.lower()
    if name == "jcsba":
        return JCSBAPolicy(K, SolverHyper(**kw.get("immune_kwargs", {}) or {}))
    if name == "random":
        return RandomPolicy(K, kw.get("n_sched", 4))
    if name in ("round_robin", "roundrobin"):
        return RoundRobinPolicy(K, kw.get("n_sched", 4))
    if name == "selection":
        return SelectionPolicy.from_modalities(K, client_modalities,
                                               kw.get("ratio", 0.4))
    if name == "dropout":
        return DropoutPolicy.from_modalities(K, client_modalities,
                                             kw.get("n_sched", 4),
                                             kw.get("p_drop", 0.3))
    raise ValueError(f"no traced policy named {name!r}")
