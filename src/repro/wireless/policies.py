"""Traced scheduling policies — every scheduler's per-round decision as one
pure jittable program.

The paper's evaluation (Figs. 4-6, Table 3) compares JCSBA against Random /
Round-Robin / Selection baselines.  Historically only JCSBA had a traced core
(``wireless.solver``); the baselines were host-side numpy loops, which locked
the fused round engine (fl/fused_round.py) to ``scheduler="jcsba"``.  This
module makes *every* policy a :class:`SchedulePolicy`: a frozen (hashable,
jit-static) object exposing

* ``init_state()`` — the policy's evolving state as a dict-of-arrays pytree
  (JCSBA: the warm-start antibody; Round-Robin: the cursor; Random /
  Selection: empty), carried through ``lax.scan`` by the fused engine and
  checkpointed via the schedulers' ``state()/load_state()`` API;
* ``step(state, data, model_dist, key)`` — one round's decision
  ``(new_state, a, B, J)`` as a pure traced function of the round context
  ``data`` (the ``solver.common.build_solver_data`` dict, f32 on device),
  the ‖θ_k − θ⁰‖ bookkeeping and a ``jax.random`` key derived from the
  round's single host seed draw.

The host-side ``Scheduler`` classes in ``schedulers.py`` are thin wrappers
that jit the *same* ``step`` — host/fused parity is by construction, not by
mirroring (tests/test_fused_round.py locks it per policy).  Random bits come
exclusively from the per-round ``key`` (one ``rng.integers(2**31)`` host draw
per round for every policy — the static rng discipline PR 3 established for
JCSBA), so fused xs pregeneration stays draw-for-draw identical to the host
loop for all policies.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .solver import SolverHyper
from .solver.jaxsolver import solve_core

POLICY_NAMES = ("jcsba", "random", "round_robin", "selection")


def equal_bandwidth_traced(a, B_max):
    """Traced twin of the baselines' equal split: B_max/n over scheduled
    clients, exact zeros elsewhere (and everywhere when nobody is scheduled).
    """
    n = a.sum()
    share = jnp.asarray(B_max, jnp.float32) / jnp.maximum(n, 1)
    return jnp.where(a, share, jnp.float32(0.0))


class SchedulePolicy:
    """Protocol for traced per-round scheduling decisions.

    Implementations must be immutable/hashable (frozen dataclasses) so they
    can ride along as static jit arguments; all evolving state flows through
    ``state``.  ``data`` is the round-context dict of
    ``solver.common.build_solver_data`` — policies read only the keys they
    need (baselines: ``B_max``; JCSBA: the full solver context).
    """
    name = "base"

    def init_state(self) -> Dict[str, np.ndarray]:
        return {}

    def step(self, state, data, model_dist, key):
        """-> (new_state, a [K] bool, B [K] f32, J scalar f32)."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class JCSBAPolicy(SchedulePolicy):
    """The paper's joint scheduling + bandwidth algorithm (Algorithm 2 +
    P4.2' + Theorem-1 bound) via the population-batched fused solver.  State
    is the warm-start antibody: the previous round's winner is written over
    population row 0, the all-zeros antibody over row 1 (so the empty
    schedule is always evaluated and J* is always finite)."""
    K: int
    hp: SolverHyper = SolverHyper()
    name = "jcsba"

    def init_state(self):
        return {"warm_a": np.zeros(self.K, bool)}

    def step(self, state, data, model_dist, key):
        warm = jnp.asarray(state["warm_a"], bool)
        seeds = jnp.stack([warm, jnp.zeros_like(warm)])
        a, J, B = solve_core(data, seeds, key, self.hp)
        return {"warm_a": a}, a, B, J


@dataclasses.dataclass(frozen=True)
class RandomPolicy(SchedulePolicy):
    """Random client subset (without replacement), equal bandwidth split."""
    K: int
    n_sched: int = 4
    name = "random"

    def step(self, state, data, model_dist, key):
        n = min(self.n_sched, self.K)
        perm = jax.random.permutation(key, self.K)
        a = jnp.zeros(self.K, bool).at[perm[:n]].set(True)
        return state, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan)


@dataclasses.dataclass(frozen=True)
class RoundRobinPolicy(SchedulePolicy):
    """Cycle through clients in fixed order, equal bandwidth.  State is the
    cursor (int32), which now checkpoints/restores with the experiment."""
    K: int
    n_sched: int = 4
    name = "round_robin"

    def init_state(self):
        return {"next": np.zeros((), np.int32)}

    def step(self, state, data, model_dist, key):
        n = min(self.n_sched, self.K)
        idx = (state["next"] + jnp.arange(n, dtype=jnp.int32)) % self.K
        a = jnp.zeros(self.K, bool).at[idx].set(True)
        new = {"next": (state["next"] + jnp.int32(self.n_sched)) % self.K}
        return new, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan)


@dataclasses.dataclass(frozen=True)
class SelectionPolicy(SchedulePolicy):
    """[26]: fixed selection ratio per modality-combination group; within
    each group pick the clients whose local model moved farthest from θ⁰.

    Group structure is static (derived from the cohort's modality ownership
    at build time): ``group_ids[k]`` is client k's group, ``group_picks``
    holds ``(group, n_pick)`` with ``n_pick = max(1, round(ratio·|group|))``.
    The per-group top-k is a stable argsort over ``model_dist`` masked to the
    group — ties resolve to the lowest client index, exactly like the old
    host loop's stable ``sorted``."""
    K: int
    group_ids: Tuple[int, ...]
    group_picks: Tuple[Tuple[int, int], ...]
    name = "selection"

    @classmethod
    def from_modalities(cls, K: int,
                        client_modalities: Optional[Sequence[Sequence[str]]],
                        ratio: float = 0.4) -> "SelectionPolicy":
        mods = client_modalities or [("m",)] * K
        gid_of: Dict[frozenset, int] = {}
        gids = [gid_of.setdefault(frozenset(m), len(gid_of)) for m in mods]
        sizes: Dict[int, int] = {}
        for g in gids:
            sizes[g] = sizes.get(g, 0) + 1
        picks = tuple(sorted((g, max(1, int(round(ratio * n))))
                             for g, n in sizes.items()))
        return cls(K, tuple(gids), picks)

    def step(self, state, data, model_dist, key):
        gid = jnp.asarray(self.group_ids, jnp.int32)
        dist = jnp.asarray(model_dist, jnp.float32)
        a = jnp.zeros(self.K, bool)
        for g, n_pick in self.group_picks:
            scores = jnp.where(gid == g, dist, -jnp.inf)
            top = jnp.argsort(-scores)[:n_pick]
            a = a.at[top].set(True)
        return state, a, equal_bandwidth_traced(a, data["B_max"]), \
            jnp.float32(jnp.nan)


# ---------------------------------------------------------------------------
# host entry point: one jitted step per (policy, pytree-signature)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames="policy")
def policy_step(policy: SchedulePolicy, state, data, model_dist, seed):
    """Jitted host-facing wrapper around ``policy.step``: derives the round's
    ``jax.random`` key from the scalar ``seed`` (a uint32 array, NOT a Python
    int — Python ints would retrace per round) exactly like the fused engine
    does from ``xs.draw_seed``, so both paths consume identical bits."""
    return policy.step(state, data, model_dist, jax.random.PRNGKey(seed))


def make_policy(name: str, K: int,
                client_modalities: Optional[Sequence[Sequence[str]]] = None,
                **kw) -> SchedulePolicy:
    name = name.lower()
    if name == "jcsba":
        return JCSBAPolicy(K, SolverHyper(**kw.get("immune_kwargs", {}) or {}))
    if name == "random":
        return RandomPolicy(K, kw.get("n_sched", 4))
    if name in ("round_robin", "roundrobin"):
        return RoundRobinPolicy(K, kw.get("n_sched", 4))
    if name == "selection":
        return SelectionPolicy.from_modalities(K, client_modalities,
                                               kw.get("ratio", 0.4))
    raise ValueError(f"no traced policy named {name!r}")
