"""Traced scheduling policies — every scheduler's per-round decision as one
pure jittable program.

The paper's evaluation (Figs. 4-6, Table 3) compares JCSBA against Random /
Round-Robin / Selection baselines.  Historically only JCSBA had a traced core
(``wireless.solver``); the baselines were host-side numpy loops, which locked
the fused round engine (fl/fused_round.py) to ``scheduler="jcsba"``.  This
module makes *every* policy a :class:`SchedulePolicy`: a frozen (hashable,
jit-static) object exposing

* ``init_state()`` — the policy's evolving state as a dict-of-arrays pytree
  (JCSBA: the warm-start antibody; Round-Robin: the cursor; Random /
  Selection: empty), carried through ``lax.scan`` by the fused engine and
  checkpointed via the schedulers' ``state()/load_state()`` API;
* ``step(state, data, model_dist, key)`` — one round's decision
  ``(new_state, a, B, J)`` as a pure traced function of the round context
  ``data`` (the ``solver.common.build_solver_data`` dict, f32 on device),
  the ‖θ_k − θ⁰‖ bookkeeping and a ``jax.random`` key derived from the
  round's single host seed draw.

The host-side ``Scheduler`` classes in ``schedulers.py`` are thin wrappers
that jit the *same* ``step`` — host/fused parity is by construction, not by
mirroring (tests/test_fused_round.py locks it per policy).  Random bits come
exclusively from the per-round ``key`` (one ``rng.integers(2**31)`` host draw
per round for every policy — the static rng discipline PR 3 established for
JCSBA), so fused xs pregeneration stays draw-for-draw identical to the host
loop for all policies.

The canonical decision surface is ``step_full(state, data, model_dist, key)
-> (state, a, B, J, drop, cohort_idx)``: the dense schedule ``a``, bandwidth
``B`` and bound value ``J``, plus a per-modality drop mask (zero rows for
policies without dropout — see :class:`DropoutPolicy`) and a **static-size
cohort index vector** ``cohort_idx [cohort_size] int32`` listing the
scheduled clients' indices (ascending, padded with unscheduled indices —
consumers neutralize padding via ``a[cohort_idx]``).  The cohort vector is
what makes the fused round's BGD/aggregation hot path O(J) instead of O(K):
the engine gathers only ``cohort_idx`` rows from the client store.  ``step``
remains as a thin 4-tuple compat adapter.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .solver import SolverHyper
from .solver.jaxsolver import solve_core

POLICY_NAMES = ("jcsba", "random", "round_robin", "selection", "dropout")


def cohort_indices(a, cohort_size: int):
    """Static-size cohort index vector from a dense schedule mask.

    Semantics are those of the stable-sort spec ``jnp.argsort(~a)
    [:cohort_size]``: scheduled clients first *in ascending index order*,
    then unscheduled padding (also ascending).  The leading ``cohort_size``
    entries are therefore every scheduled client (provided the policy's
    ``cohort_size`` upper-bounds its schedule width) plus padding slots that
    point at unscheduled clients — downstream masks (``a[cohort_idx]``, the
    Eq. 12 upload masks) neutralize the padding, so duplicate-free indices
    are guaranteed by construction.

    Implemented as ``lax.top_k`` over the key ``(a ? 3K : K) - k`` — every
    scheduled key outranks every unscheduled one and both groups descend
    with the client index, so the result is *bit-identical* to the argsort
    spec (property-locked in tests/test_cohort_gather.py) at O(K log J)
    instead of the full sort's O(K log K): at K=100k the full sort alone
    costs more than the entire cohort round."""
    a = jnp.asarray(a, bool)
    K = a.shape[0]
    key = jnp.where(a, 3 * K, K) - jnp.arange(K)
    return lax.top_k(key, cohort_size)[1].astype(jnp.int32)


def equal_bandwidth_traced(a, B_max):
    """Traced twin of the baselines' equal split: B_max/n over scheduled
    clients, exact zeros elsewhere (and everywhere when nobody is scheduled).
    """
    n = a.sum()
    share = jnp.asarray(B_max, jnp.float32) / jnp.maximum(n, 1)
    return jnp.where(a, share, jnp.float32(0.0))


class SchedulePolicy:
    """Protocol for traced per-round scheduling decisions.

    Implementations must be immutable/hashable (frozen dataclasses) so they
    can ride along as static jit arguments; all evolving state flows through
    ``state``.  ``data`` is the round-context dict of
    ``solver.common.build_solver_data`` — policies read only the keys they
    need (baselines: ``B_max``; JCSBA: the full solver context).
    """
    name = "base"
    #: modality names addressing ``step_full``'s drop-mask rows (empty for
    #: policies without dropout)
    drop_mods: Tuple[str, ...] = ()

    @property
    def cohort_size(self) -> int:
        """Static upper bound on how many clients the policy ever schedules
        in one round — the length of ``step_full``'s cohort index vector and
        hence the O(J) working-set size of the fused round's gather path.
        Defaults to K (dense: always safe); bounded policies override."""
        return self.K

    def init_state(self) -> Dict[str, np.ndarray]:
        return {}

    def step_full(self, state, data, model_dist, key):
        """The canonical decision: ``-> (new_state, a [K] bool, B [K] f32,
        J scalar f32, drop [M_drop, K] bool, cohort_idx [cohort_size] int32)``
        with drop rows in ``self.drop_mods`` order (zero rows for policies
        without dropout, so consumers branch on the *static* row count at
        trace time) and the cohort vector from :func:`cohort_indices`."""
        raise NotImplementedError

    def step(self, state, data, model_dist, key):
        """Thin compat adapter: the classic 4-tuple projection of
        ``step_full`` — ``(new_state, a, B, J)``."""
        return self.step_full(state, data, model_dist, key)[:4]

    def _finish(self, state, a, B, J, drop=None):
        """Assemble the canonical 6-tuple from a policy's core decision:
        appends the zero-row drop mask when the policy has none, and the
        static-size cohort index vector."""
        if drop is None:
            drop = jnp.zeros((0, a.shape[0]), bool)
        return state, a, B, J, drop, cohort_indices(a, self.cohort_size)


@dataclasses.dataclass(frozen=True)
class JCSBAPolicy(SchedulePolicy):
    """The paper's joint scheduling + bandwidth algorithm (Algorithm 2 +
    P4.2' + Theorem-1 bound) via the population-batched fused solver.  State
    is the warm-start antibody: the previous round's winner is written over
    population row 0, the all-zeros antibody over row 1 (so the empty
    schedule is always evaluated and J* is always finite).

    ``max_cohort`` optionally caps the cohort vector's static size for
    population-scale runs (the solver may in principle schedule anyone, so
    the default is the always-safe dense K)."""
    K: int
    hp: SolverHyper = SolverHyper()
    max_cohort: Optional[int] = None
    name = "jcsba"

    @property
    def cohort_size(self) -> int:
        return self.K if self.max_cohort is None \
            else min(self.max_cohort, self.K)

    def init_state(self):
        return {"warm_a": np.zeros(self.K, bool)}

    def step_full(self, state, data, model_dist, key):
        warm = jnp.asarray(state["warm_a"], bool)
        seeds = jnp.stack([warm, jnp.zeros_like(warm)])
        a, J, B = solve_core(data, seeds, key, self.hp)
        return self._finish({"warm_a": a}, a, B, J)


@dataclasses.dataclass(frozen=True)
class RandomPolicy(SchedulePolicy):
    """Random client subset (without replacement), equal bandwidth split."""
    K: int
    n_sched: int = 4
    name = "random"

    @property
    def cohort_size(self) -> int:
        return min(self.n_sched, self.K)

    def step_full(self, state, data, model_dist, key):
        # uniform n-subset via Gumbel/uniform top-k: every fixed-size subset
        # is equally likely (symmetry of iid uniforms), same distribution as
        # taking a full permutation's head — but O(K log n), which matters
        # at population scale (jax.random.permutation costs ~66 ms at
        # K=100k on CPU, dominating the whole cohort round)
        n = min(self.n_sched, self.K)
        u = jax.random.uniform(key, (self.K,))
        a = jnp.zeros(self.K, bool).at[lax.top_k(u, n)[1]].set(True)
        return self._finish(state, a,
                            equal_bandwidth_traced(a, data["B_max"]),
                            jnp.float32(jnp.nan))


@dataclasses.dataclass(frozen=True)
class RoundRobinPolicy(SchedulePolicy):
    """Cycle through clients in fixed order, equal bandwidth.  State is the
    cursor (int32), which now checkpoints/restores with the experiment."""
    K: int
    n_sched: int = 4
    name = "round_robin"

    @property
    def cohort_size(self) -> int:
        return min(self.n_sched, self.K)

    def init_state(self):
        return {"next": np.zeros((), np.int32)}

    def step_full(self, state, data, model_dist, key):
        n = min(self.n_sched, self.K)
        idx = (state["next"] + jnp.arange(n, dtype=jnp.int32)) % self.K
        a = jnp.zeros(self.K, bool).at[idx].set(True)
        new = {"next": (state["next"] + jnp.int32(self.n_sched)) % self.K}
        return self._finish(new, a,
                            equal_bandwidth_traced(a, data["B_max"]),
                            jnp.float32(jnp.nan))


@dataclasses.dataclass(frozen=True)
class SelectionPolicy(SchedulePolicy):
    """[26]: fixed selection ratio per modality-combination group; within
    each group pick the clients whose local model moved farthest from θ⁰.

    Group structure is static (derived from the cohort's modality ownership
    at build time): ``group_ids[k]`` is client k's group, ``group_picks``
    holds ``(group, n_pick)`` with ``n_pick = max(1, round(ratio·|group|))``.
    The per-group top-k is a stable argsort over ``model_dist`` masked to the
    group — ties resolve to the lowest client index, exactly like the old
    host loop's stable ``sorted``."""
    K: int
    group_ids: Tuple[int, ...]
    group_picks: Tuple[Tuple[int, int], ...]
    name = "selection"

    @classmethod
    def from_modalities(cls, K: int,
                        client_modalities: Optional[Sequence[Sequence[str]]],
                        ratio: float = 0.4) -> "SelectionPolicy":
        mods = client_modalities or [("m",)] * K
        gid_of: Dict[frozenset, int] = {}
        gids = [gid_of.setdefault(frozenset(m), len(gid_of)) for m in mods]
        sizes: Dict[int, int] = {}
        for g in gids:
            sizes[g] = sizes.get(g, 0) + 1
        picks = tuple(sorted((g, max(1, int(round(ratio * n))))
                             for g, n in sizes.items()))
        return cls(K, tuple(gids), picks)

    @property
    def cohort_size(self) -> int:
        return min(self.K, sum(n for _, n in self.group_picks))

    def step_full(self, state, data, model_dist, key):
        gid = jnp.asarray(self.group_ids, jnp.int32)
        dist = jnp.asarray(model_dist, jnp.float32)
        a = jnp.zeros(self.K, bool)
        for g, n_pick in self.group_picks:
            scores = jnp.where(gid == g, dist, -jnp.inf)
            top = jnp.argsort(-scores)[:n_pick]
            a = a.at[top].set(True)
        return self._finish(state, a,
                            equal_bandwidth_traced(a, data["B_max"]),
                            jnp.float32(jnp.nan))


def dropout_draws(key, K: int):
    """The dropout baseline's per-client uniforms: ``(u_drop [K], u_which
    [K])`` — drop-the-coin and which-modality draws for every client.

    Client k's pair comes from ``fold_in(key, k)``, so a draw depends on
    exactly (round key, client index): growing or shrinking the cohort never
    perturbs the bits of the clients that remain (property-tested in
    tests/test_fused_properties.py)."""
    def one(k):
        return jax.random.uniform(jax.random.fold_in(key, k), (2,))
    u = jax.vmap(one)(jnp.arange(K, dtype=jnp.uint32))
    return u[:, 0], u[:, 1]


@dataclasses.dataclass(frozen=True)
class DropoutPolicy(SchedulePolicy):
    """[28]: random scheduling + modality dropout — scheduled *multimodal*
    clients drop one uniformly-chosen owned modality with probability
    ``p_drop`` (unimodal clients never drop, so nobody is ever dropped to
    zero modalities).  The drop decision is part of the traced decision:
    ``step_full`` emits a ``[M, K]`` drop mask whose rows follow
    ``drop_mods`` (the cohort's modality names, sorted — the same order the
    old host loop's ``rng.choice(sorted(mods))`` ranked candidates).

    Ownership is static (``owns[i][k]`` ⇔ client k owns ``drop_mods[i]``),
    so which-modality draws map to mask rows by the precomputed ownership
    ranks; all randomness comes from the single round key: one split for the
    schedule subset, one ``dropout_draws`` stream for the drop bits."""
    K: int
    drop_mods: Tuple[str, ...] = ()
    owns: Tuple[Tuple[bool, ...], ...] = ()  # [M][K], static
    n_sched: int = 4
    p_drop: float = 0.3
    name = "dropout"

    @classmethod
    def from_modalities(cls, K: int,
                        client_modalities: Optional[Sequence[Sequence[str]]],
                        n_sched: int = 4, p_drop: float = 0.3
                        ) -> "DropoutPolicy":
        mods = client_modalities or [("m",)] * K
        names = tuple(sorted({m for ms in mods for m in ms}))
        owns = tuple(tuple(m in ms for ms in mods) for m in names)
        return cls(K, names, owns, n_sched, float(p_drop))

    def drop_mask(self, a, key):
        """[M, K] bool — modality ``drop_mods[i]`` dropped by client k."""
        owns = jnp.asarray(self.owns, bool)                  # [M, K]
        n_owned = owns.sum(0)                                # [K]
        u_drop, u_which = dropout_draws(key, self.K)
        do = jnp.asarray(a, bool) & (n_owned > 1) & (u_drop < self.p_drop)
        # uniform pick among the client's owned modalities, in row order:
        # rank[i, k] = #owned rows above i; the pick is the rank-th owned row
        which = jnp.minimum((u_which * n_owned).astype(jnp.int32),
                            jnp.maximum(n_owned - 1, 0))
        rank = jnp.cumsum(owns, axis=0) - owns
        return do[None] & owns & (rank == which[None])

    @property
    def cohort_size(self) -> int:
        return min(self.n_sched, self.K)

    def step_full(self, state, data, model_dist, key):
        k_sub, k_drop = jax.random.split(key)
        n = min(self.n_sched, self.K)
        perm = jax.random.permutation(k_sub, self.K)
        a = jnp.zeros(self.K, bool).at[perm[:n]].set(True)
        return self._finish(state, a,
                            equal_bandwidth_traced(a, data["B_max"]),
                            jnp.float32(jnp.nan), self.drop_mask(a, k_drop))


# ---------------------------------------------------------------------------
# host entry point: one jitted step per (policy, pytree-signature)
# ---------------------------------------------------------------------------
@partial(jax.jit, static_argnames="policy")
def policy_step(policy: SchedulePolicy, state, data, model_dist, seed):
    """Jitted host-facing wrapper around ``policy.step_full``: derives the
    round's ``jax.random`` key from the scalar ``seed`` (a uint32 array, NOT
    a Python int — Python ints would retrace per round) exactly like the
    fused engine does from ``xs.draw_seed``, so both paths consume identical
    bits.  Returns the canonical 6-tuple ``(state, a, B, J, drop,
    cohort_idx)``; the drop mask has zero rows for policies without
    dropout."""
    return policy.step_full(state, data, model_dist, jax.random.PRNGKey(seed))


def make_policy(name: str, K: int,
                client_modalities: Optional[Sequence[Sequence[str]]] = None,
                **kw) -> SchedulePolicy:
    name = name.lower()
    if name == "jcsba":
        return JCSBAPolicy(K, SolverHyper(**kw.get("immune_kwargs", {}) or {}),
                           kw.get("max_cohort"))
    if name == "random":
        return RandomPolicy(K, kw.get("n_sched", 4))
    if name in ("round_robin", "roundrobin"):
        return RoundRobinPolicy(K, kw.get("n_sched", 4))
    if name == "selection":
        return SelectionPolicy.from_modalities(K, client_modalities,
                                               kw.get("ratio", 0.4))
    if name == "dropout":
        return DropoutPolicy.from_modalities(K, client_modalities,
                                             kw.get("n_sched", 4),
                                             kw.get("p_drop", 0.3))
    raise ValueError(f"no traced policy named {name!r}")
