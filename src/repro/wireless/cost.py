"""Latency & energy models — Eqs. (15)-(20)."""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .params import WirelessParams, cpu_cycles_per_sample, upload_bits
from .channel import uplink_rate


@dataclasses.dataclass
class ClientCost:
    """Static per-client quantities (channel-independent)."""
    gamma_bits: np.ndarray      # Γ_k upload size [bit]
    tau_cmp: np.ndarray         # computation latency [s] (Eq. 17)
    e_cmp: np.ndarray           # computation energy [J] (Eq. 18)

    def tau_residual(self, params: WirelessParams) -> np.ndarray:
        """τ_max − τ_cmp_k — the communication-latency budget left per client
        (the RHS denominator of the In1 constraint in P4.2')."""
        return params.tau_max - self.tau_cmp


def client_costs(data_sizes: Sequence[int],
                 client_modalities: Sequence[Sequence[str]],
                 profile, params: WirelessParams) -> ClientCost:
    K = len(data_sizes)
    gam = np.zeros(K)
    tcmp = np.zeros(K)
    ecmp = np.zeros(K)
    for k in range(K):
        gam[k] = upload_bits(client_modalities[k], profile)
        phi = cpu_cycles_per_sample(client_modalities[k], profile, params.beta0)
        tcmp[k] = data_sizes[k] * phi / params.f_cpu
        ecmp[k] = params.alpha * data_sizes[k] * params.f_cpu ** 2 * phi
    return ClientCost(gam, tcmp, ecmp)


def population_costs(has_modality, modalities: Sequence[str],
                     sizes: np.ndarray, profile,
                     params: WirelessParams) -> ClientCost:
    """Vectorized Eqs. 15-18 over ownership masks — ``client_costs`` without
    the per-client Python loop, for O(10⁴–10⁶) populations.

    ``has_modality[m]`` is a bool [K] ownership mask (a ``ClientStore``
    field), ``sizes`` the per-client sample counts D_k."""
    has = {m: np.asarray(has_modality[m], bool) for m in modalities}
    # Γ_k = Σ_{m∈M_k} l_m (Eq. 15);  Φ_k = Σ_{m∈M_k}(β_m + β₀) − β₀ (Eq. 17)
    gam = sum(np.where(has[m], profile[m][0], 0.0) for m in modalities)
    owned = sum(has[m].astype(np.int64) for m in modalities)
    phi = (sum(np.where(has[m], profile[m][1] + params.beta0, 0.0)
               for m in modalities)
           - params.beta0 * (owned > 0))
    D = np.asarray(sizes, np.float64)
    tau_cmp = D * phi / params.f_cpu                                # Eq. 17
    e_cmp = params.alpha * D * params.f_cpu ** 2 * phi              # Eq. 18
    return ClientCost(np.asarray(gam, np.float64), tau_cmp, e_cmp)


def com_latency(B: np.ndarray, h: np.ndarray, gamma_bits: np.ndarray,
                params: WirelessParams) -> np.ndarray:
    """τ_k^com = Γ_k / r_k (Eq. 15)."""
    r = uplink_rate(B, h, params)
    with np.errstate(divide="ignore"):
        t = gamma_bits / np.maximum(r, 1e-300)
    return np.where(B > 0, t, np.inf)


def com_energy(tau_com: np.ndarray, params: WirelessParams) -> np.ndarray:
    """e_k^com = p τ_k^com (Eq. 16)."""
    return params.p_tx * np.where(np.isfinite(tau_com), tau_com, 0.0)


def residual_energy(a: np.ndarray, e_com: np.ndarray, e_cmp: np.ndarray,
                    params: WirelessParams) -> np.ndarray:
    """q_k = E_add − a_k (e_com + e_cmp) (§III-C)."""
    return params.E_add - a * (e_com + e_cmp)
