"""Lyapunov virtual energy queues (§V-A).

Q_k^{t+1} = max(Q_k^t − q_k^t, 0) with q_k = E_add − a_k (e_com + e_cmp).
Mean-rate stability of Q is equivalent to the long-term energy constraint C5
(Eq. 29); the drift-plus-penalty weight V trades energy for MFL performance
(Fig. 4).
"""
from __future__ import annotations

import numpy as np


class EnergyQueues:
    def __init__(self, K: int):
        self.Q = np.zeros(K)
        self.spent = np.zeros(K)       # cumulative actual energy [J]
        self.t = 0

    def step(self, a: np.ndarray, e_com: np.ndarray, e_cmp: np.ndarray,
             E_add: float) -> np.ndarray:
        a = np.asarray(a, float)
        used = a * (e_com + e_cmp)
        q = E_add - used
        self.Q = np.maximum(self.Q - q, 0.0)
        self.spent += used
        self.t += 1
        return q

    def mean_queue(self) -> float:
        return float(self.Q.mean())

    def stability_metric(self) -> float:
        """|Q^T|/T → 0 is C5' (Eq. 29)."""
        return float(np.abs(self.Q).mean() / max(self.t, 1))
