"""Lyapunov virtual energy queues (§V-A).

Q_k^{t+1} = max(Q_k^t − q_k^t, 0) with q_k = E_add − a_k (e_com + e_cmp).
Mean-rate stability of Q is equivalent to the long-term energy constraint C5
(Eq. 29); the drift-plus-penalty weight V trades energy for MFL performance
(Fig. 4).
"""
from __future__ import annotations

import numpy as np


def queue_update(Q, used, E_add):
    """Pure functional Q_k^{t+1} = max(Q_k − (E_add − used_k), 0), where
    ``used_k = a_k (e_com_k + e_cmp_k)`` is the round's actual energy draw.

    Backend-agnostic (works on numpy and jnp arrays alike), so the batched
    solver's scenario-sweep driver can run the queue recursion inside a
    ``lax.scan`` over rounds.  ``EnergyQueues.step`` is the stateful host-side
    twin used by the FL runtime."""
    Qn = Q - (E_add - used)
    return Qn * (Qn > 0)


class EnergyQueues:
    def __init__(self, K: int):
        self.Q = np.zeros(K)
        self.spent = np.zeros(K)       # cumulative actual energy [J]
        self.t = 0

    def step(self, a: np.ndarray, e_com: np.ndarray, e_cmp: np.ndarray,
             E_add: float) -> np.ndarray:
        a = np.asarray(a, float)
        used = a * (e_com + e_cmp)
        self.Q = np.asarray(queue_update(self.Q, used, E_add))
        self.spent += used
        self.t += 1
        return E_add - used

    def mean_queue(self) -> float:
        return float(self.Q.mean())

    def stability_metric(self) -> float:
        """|Q^T|/T → 0 is C5' (Eq. 29)."""
        return float(np.abs(self.Q).mean() / max(self.t, 1))
