"""Wireless system parameters — paper Table 2, plus simulation constants.

All Table-2 values are kept verbatim.  Constants the paper does not publish
(composite antenna/other gains folded into h_k, β₀ fusion cycles, the fading
law) are documented here and in DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class WirelessParams:
    # Table 2 (verbatim)
    B_max: float = 10e6                 # total uplink bandwidth [Hz]
    tau_max: float = 0.01               # per-round latency budget [s]
    p_tx_dbm: float = 23.0              # uplink transmit power [dBm]
    N0_dbm_hz: float = -174.0           # noise PSD [dBm/Hz]
    K: int = 10                         # clients
    E_add: float = 0.01                 # per-round energy allowance [J]
    f_cpu: float = 1.55e9               # CPU frequency [Hz]
    alpha: float = 1e-27                # energy coefficient
    # Simulation constants (not in Table 2)
    cell_radius_m: float = 500.0
    extra_gain_db: float = 60.0         # BS+UE antenna & other gains folded in
    beta0: float = 100.0                # fusion CPU cycles per sample pair

    @property
    def p_tx(self) -> float:
        return 10 ** (self.p_tx_dbm / 10) / 1000.0          # [W]

    @property
    def N0(self) -> float:
        return 10 ** (self.N0_dbm_hz / 10) / 1000.0         # [W/Hz]


# Per-modality upload bits l_m and per-sample CPU cycles beta_m (Table 2).
MODALITY_PROFILES: Dict[str, Dict[str, Tuple[float, float]]] = {
    "crema_d": {
        "audio": (562400.0, 2000.0),
        "image": (557056.0, 8000.0),
    },
    "iemocap": {
        "audio": (562400.0, 2000.0),
        "text": (1145280.0, 4500.0),
    },
}


def upload_bits(modalities, profile: Dict[str, Tuple[float, float]]) -> float:
    """Γ_k = Σ_{m∈M_k} l_m (Eq. 15)."""
    return float(sum(profile[m][0] for m in modalities))


def cpu_cycles_per_sample(modalities, profile, beta0: float) -> float:
    """Φ_k = Σ_{m∈M_k}(β_m + β₀) − β₀ (Eq. 17)."""
    mods = list(modalities)
    if not mods:
        return 0.0
    return float(sum(profile[m][1] + beta0 for m in mods) - beta0)
