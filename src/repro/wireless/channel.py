"""Cellular uplink channel simulation (§III-A / §VI setup).

K clients uniform in a 500 m disc around the BS; channel gain h_k combines
3GPP log-distance path loss (128.1 + 37.6·log10 d_km), Rayleigh small-scale
fading (redrawn every communication round) and a composite antenna/other gain
(``extra_gain_db`` — the paper folds these into h_k without publishing them).
"""
from __future__ import annotations

import numpy as np

from .params import WirelessParams


class Channel:
    def __init__(self, params: WirelessParams, rng: np.random.Generator):
        self.params = params
        self.rng = rng
        r = params.cell_radius_m * np.sqrt(rng.uniform(0.02, 1.0, params.K))
        self.dist_m = r                                     # BS at the centre

    def path_gain(self) -> np.ndarray:
        pl_db = 128.1 + 37.6 * np.log10(self.dist_m / 1000.0)
        return 10 ** ((-pl_db + self.params.extra_gain_db) / 10.0)

    def draw(self) -> np.ndarray:
        """h_k for one communication round (large-scale x Rayleigh power)."""
        rayleigh_power = self.rng.exponential(1.0, self.params.K)
        return self.path_gain() * rayleigh_power


def uplink_rate(B: np.ndarray, h: np.ndarray, params: WirelessParams) -> np.ndarray:
    """Shannon/FDMA rate r_k = B_k log2(1 + p h_k / (B_k N0)) (Eq. 13)."""
    B = np.asarray(B, float)
    with np.errstate(divide="ignore", invalid="ignore"):
        snr = params.p_tx * h / np.maximum(B * params.N0, 1e-300)
        r = B * np.log2(1.0 + snr)
    return np.where(B > 0, r, 0.0)


def rate_ceiling(h: np.ndarray, params: WirelessParams) -> np.ndarray:
    """lim_{B->inf} r(B) = p h / (N0 ln 2) — feasibility ceiling."""
    return params.p_tx * h / (params.N0 * np.log(2.0))
