"""Client partitioning with modality heterogeneity (§VI "Datasets").

The paper quantifies modality heterogeneity by a missing-modality ratio ω:
ω_m = 0.3 means 30% of clients lack modality m.  We split the dataset into K
equal-ish client shards and remove each modality from a ⌊ω_m·K⌋-sized client
subset chosen so that every client keeps at least one modality (matching
Fig. 1 where client 1 lacks image but keeps audio).

Construction (``missing_counts`` / ``missing_masks``, shared by ``partition``
and ``synthetic_population``): lay the per-modality missing windows end to
end around one random permutation of the K clients, wrapping modulo K.  Each
window has length n_m = ⌊ω_m·K⌋ ≤ K-1, so no modality is removed from the
same client twice, and as long as the total Σ_m n_m ≤ K·(M-1) no client can
collect marks from all M modalities (max per-client load is ⌈Σn_m / K⌉).
When Σ_m n_m exceeds that capacity — e.g. M=2, ω=0.6, where exact targets
are combinatorially impossible under keep-≥1 — the targets are shaved
largest-first (water-fill) down to capacity instead of silently overlapping;
``missing_counts`` exposes the realized counts.  Genuinely infeasible specs
(ω_m ≥ 1, which would strip a modality of every owner, or removing the only
modality when M=1) raise ``ValueError``.

For Σ_m n_m ≤ K the windows never wrap and this reproduces the historical
disjoint-block assignment bit-for-bit (same rng stream); seeds only differ
in the previously-broken ω > 1/M regime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

import jax
import numpy as np

from .synthetic import MultimodalDataset


@dataclasses.dataclass
class ClientData:
    dataset: MultimodalDataset          # only this client's modalities
    modalities: Tuple[str, ...]

    @property
    def size(self) -> int:
        return len(self.dataset)


@dataclasses.dataclass
class StackedClients:
    """Dense client-major stack of an entire cohort, for the batched round
    engine (fl/runtime.py).

    Every modality is materialised for every client at a fixed ``max_batch``
    (the largest client shard), so one jitted ``vmap`` can sweep the whole
    cohort without ragged shapes:

    * ``features[m]`` — [K, max_batch, ...] float32, zero-padded; a client
      that lacks modality m gets an all-zero block (masked out of the loss
      by ``has_modality``).
    * ``labels`` / ``sample_mask`` — [K, max_batch]; ``sample_mask[k, i]`` is
      1.0 for the ``sizes[k]`` real samples and 0.0 for padding.
    * ``has_modality[m]`` — bool [K], client-owns-modality mask.

    Built once per cohort (experiment init) and kept device-resident.
    """
    features: Dict[str, np.ndarray]
    labels: np.ndarray
    sample_mask: np.ndarray
    has_modality: Dict[str, np.ndarray]
    sizes: np.ndarray
    modalities: Tuple[str, ...]

    @property
    def K(self) -> int:
        return len(self.sizes)

    @property
    def max_batch(self) -> int:
        return self.labels.shape[1]


def stack_clients(clients: Sequence[ClientData],
                  all_modalities: Sequence[str]) -> StackedClients:
    """Pad + stack a list of per-client shards into a StackedClients."""
    K = len(clients)
    N = max(c.size for c in clients)
    labels = np.zeros((K, N), np.int32)
    smask = np.zeros((K, N), np.float32)
    has = {m: np.array([m in c.modalities for c in clients])
           for m in all_modalities}
    feats: Dict[str, np.ndarray] = {}
    for m in all_modalities:
        owners = np.flatnonzero(has[m])
        assert owners.size, f"no client owns modality {m!r}"
        shape = clients[owners[0]].dataset.features[m].shape[1:]
        feats[m] = np.zeros((K, N) + shape, np.float32)
    for k, c in enumerate(clients):
        n = c.size
        labels[k, :n] = c.dataset.labels
        smask[k, :n] = 1.0
        for m in c.modalities:
            feats[m][k, :n] = c.dataset.features[m]
    sizes = np.array([c.size for c in clients], np.int64)
    return StackedClients(feats, labels, smask, has, sizes,
                          tuple(all_modalities))


# ---------------------------------------------------------------------------
# ClientStore — the device-resident population store the cohort-gather fused
# round reads from.  Unlike StackedClients (a host-side staging structure),
# the store is a registered pytree whose every data leaf carries a leading
# client axis, so it (a) rides through jit/shard_map boundaries directly and
# (b) shards over the 2-D mesh's "clients" axis (launch/sharding.py) — the
# O(K·N·d) feature stacks live partitioned across devices while the round
# program gathers only the scheduled cohort's J rows (``take``).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClientStore:
    """Per-client population data, one leading client axis on every leaf.

    * ``features[m]`` [K, N, ...] f32 (zero blocks for non-owners/padding)
    * ``labels`` [K, N] i32 / ``sample_mask`` [K, N] f32
    * ``has_modality[m]`` [K] bool
    * ``sizes`` [K] f32 — D_k, the Eq. 12 weight numerators
    * ``gamma_bits`` / ``tau_cmp`` / ``e_cmp`` [K] f32 — the wireless cost
      vectors (Eqs. 15-18), gathered per cohort alongside the data

    ``take(idx)`` gathers cohort rows into a J-sized store of the same
    structure; under a client-sharded mesh each shard holds a K/n_shards
    slice of every leaf and cohort gathers become masked cross-shard
    reductions (fl/fused_round.py).
    """
    features: Dict[str, object]
    labels: object
    sample_mask: object
    has_modality: Dict[str, object]
    sizes: object
    gamma_bits: object
    tau_cmp: object
    e_cmp: object
    modalities: Tuple[str, ...]

    @property
    def K(self) -> int:
        return int(self.labels.shape[0])

    def take(self, idx) -> "ClientStore":
        """Cohort gather: ``jnp.take`` over the client axis of every data
        leaf (clipping gather — downstream availability masks neutralize any
        padding slot)."""
        import jax.numpy as jnp
        idx = jnp.asarray(idx, jnp.int32)

        def g(x):
            return jnp.take(jnp.asarray(x), idx, axis=0)
        return ClientStore({m: g(v) for m, v in self.features.items()},
                           g(self.labels), g(self.sample_mask),
                           {m: g(v) for m, v in self.has_modality.items()},
                           g(self.sizes), g(self.gamma_bits),
                           g(self.tau_cmp), g(self.e_cmp), self.modalities)


jax.tree_util.register_dataclass(
    ClientStore,
    data_fields=["features", "labels", "sample_mask", "has_modality",
                 "sizes", "gamma_bits", "tau_cmp", "e_cmp"],
    meta_fields=["modalities"])


def build_client_store(stacked: StackedClients, gamma_bits, tau_cmp,
                       e_cmp) -> ClientStore:
    """Assemble a ClientStore from a staged StackedClients plus the cohort's
    wireless cost vectors (``wireless.cost.ClientCost`` arrays)."""
    return ClientStore(
        {m: np.asarray(v, np.float32) for m, v in stacked.features.items()},
        np.asarray(stacked.labels, np.int32),
        np.asarray(stacked.sample_mask, np.float32),
        {m: np.asarray(v, bool) for m, v in stacked.has_modality.items()},
        np.asarray(stacked.sizes, np.float32),
        np.asarray(gamma_bits, np.float32),
        np.asarray(tau_cmp, np.float32),
        np.asarray(e_cmp, np.float32),
        tuple(stacked.modalities))


# ---------------------------------------------------------------------------
# Missing-modality assignment (shared by partition / synthetic_population)
# ---------------------------------------------------------------------------
def normalize_omegas(omega, modalities: Sequence[str]) -> Tuple[float, ...]:
    """Broadcast a scalar ω / per-modality mapping / sequence to one ω_m per
    modality, in ``sorted(modalities)`` order."""
    mods = tuple(sorted(modalities))
    if isinstance(omega, Mapping):
        unknown = set(omega) - set(mods)
        if unknown:
            raise ValueError(f"omega names unknown modalities {sorted(unknown)}")
        return tuple(float(omega.get(m, 0.0)) for m in mods)
    if np.ndim(omega) == 0:
        return (float(omega),) * len(mods)
    omegas = tuple(float(w) for w in omega)
    if len(omegas) != len(mods):
        raise ValueError(
            f"got {len(omegas)} omega values for {len(mods)} modalities")
    return omegas


def missing_counts(K: int, omegas: Sequence[float]) -> np.ndarray:
    """Realized per-modality missing-set sizes.

    Targets are ⌊ω_m·K⌋.  Keeping every client ≥1 modality bounds the total
    at K·(M-1) (each client absorbs at most M-1 marks); oversubscribed
    targets are shaved largest-first (water-fill) to that capacity, ties
    broken toward lower modality index.  Raises ``ValueError`` for ω_m
    outside [0, 1) or when removal is infeasible outright (M = 1)."""
    omegas = np.asarray(omegas, float)
    M = omegas.size
    if np.any((omegas < 0.0) | (omegas >= 1.0)):
        raise ValueError(
            f"omega must lie in [0, 1) per modality (got {omegas.tolist()}): "
            "omega_m >= 1 strips modality m from every client")
    counts = np.floor(omegas * K).astype(int)
    cap = K * (M - 1)
    if counts.sum() > cap and cap == 0:
        raise ValueError(
            "cannot remove the only modality: with M=1 any omega*M >= 1/K "
            "leaves clients with zero modalities")
    if counts.sum() <= cap:
        return counts
    # water-fill: largest level t with sum(min(counts, t)) <= cap, then hand
    # the remainder to the largest-target modalities (stable tie-break)
    lo, hi = 0, int(counts.max())
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if int(np.minimum(counts, mid).sum()) <= cap:
            lo = mid
        else:
            hi = mid - 1
    out = np.minimum(counts, lo)
    eligible = np.flatnonzero(counts > out)
    order = eligible[np.argsort(-counts[eligible], kind="stable")]
    out[order[:cap - int(out.sum())]] += 1
    return out


def missing_masks(K: int, omegas: Sequence[float], rng) -> np.ndarray:
    """Bool [M, K]: ``mask[m, k]`` ⇔ client k is missing modality m.

    One permutation of the clients, per-modality windows of ``missing_counts``
    lengths laid end to end modulo K — every client keeps ≥1 modality and
    no modality loses every owner (n_m ≤ K-1)."""
    counts = missing_counts(K, omegas)
    order = rng.permutation(K)
    miss = np.zeros((counts.size, K), bool)
    c = 0
    for m, n in enumerate(counts):
        miss[m, order[(c + np.arange(n)) % K]] = True
        c += int(n)
    assert not miss.all(axis=0).any(), "internal: client lost every modality"
    return miss


def synthetic_population(K: int, n_per_client: int,
                         feature_shapes: Mapping[str, Sequence[int]],
                         n_classes: int, omega,
                         seed: int = 0, snr=1.0) -> ClientStore:
    """Vectorized population builder for O(10⁴–10⁶) clients.

    ``partition``/``stack_clients`` loop per client in Python — fine at
    K≈50, prohibitive at K=100k.  This builds the same modality-
    heterogeneity structure (``missing_masks``: ⌊ω_m·K⌋-sized missing sets,
    every client keeps ≥1 modality, every modality keeps ≥1 owner) with pure
    array ops.  ``omega`` and ``snr`` broadcast like in ``partition``: a
    scalar, a per-modality mapping, or a sequence in sorted-modality order.

    Features are class-conditional — per-class prototype × snr_m plus unit
    noise, the same separable structure as data/synthetic.py — so
    population-scale eval is learnable rather than chance-level.  Cost
    vectors are returned as zeros; callers fill them via
    ``dataclasses.replace`` (see ``wireless.cost.population_costs``)."""
    rng = np.random.default_rng(seed)
    mods = tuple(sorted(feature_shapes))
    omegas = normalize_omegas(omega, mods)
    snrs = normalize_omegas(snr, mods)      # same broadcast rules, no bound
    miss = missing_masks(K, omegas, rng)
    has = {m: ~miss[i] for i, m in enumerate(mods)}
    for m in mods:
        assert has[m].any(), f"no client owns modality {m!r}"
    labels = rng.integers(0, n_classes, (K, n_per_client)).astype(np.int32)
    feats: Dict[str, np.ndarray] = {}
    for i, m in enumerate(mods):
        shape = tuple(feature_shapes[m])
        protos = rng.standard_normal((n_classes,) + shape).astype(np.float32)
        noise = rng.standard_normal(
            (K, n_per_client) + shape).astype(np.float32)
        own = has[m].reshape((K,) + (1,) * (len(shape) + 1))
        feats[m] = (protos[labels] * np.float32(snrs[i]) + noise) * own
    zeros = np.zeros(K, np.float32)
    return ClientStore(feats, labels, np.ones((K, n_per_client), np.float32),
                       has, np.full(K, float(n_per_client), np.float32),
                       zeros, zeros.copy(), zeros.copy(), mods)


def _dirichlet_shards(ds: MultimodalDataset, K: int, alpha: float,
                      rng) -> List[np.ndarray]:
    """Label-skewed shards: per-class proportions ~ Dirichlet(alpha).
    Small alpha = strong non-IID (the data-heterogeneity regime of the
    paper's companion line of work [15])."""
    shards: List[list] = [[] for _ in range(K)]
    for c in range(ds.n_classes):
        idx_c = np.flatnonzero(ds.labels == c)
        rng.shuffle(idx_c)
        p = rng.dirichlet([alpha] * K)
        cuts = (np.cumsum(p) * len(idx_c)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx_c, cuts)):
            shards[k].extend(part.tolist())
    # rebalance BEFORE materialising so donated samples move, not duplicate.
    # Donors must keep >= 1 sample themselves, or a large-K / small-N split
    # can pop a shard straight back to empty (the shard it just filled, even).
    for k in range(K):
        if not shards[k]:                     # guarantee non-empty clients
            sizes = [len(x) for x in shards]
            donor = int(np.argmax(sizes))
            if sizes[donor] < 2:
                raise ValueError(
                    f"cannot rebalance Dirichlet shards: only {len(ds)} "
                    f"samples for K={K} clients")
            shards[k].append(shards[donor].pop())
    return [np.asarray(s, int) for s in shards]


def partition(ds: MultimodalDataset, K: int, omega,
              seed: int = 0,
              dirichlet_alpha: float = 0.0) -> List[ClientData]:
    """``dirichlet_alpha > 0`` adds label skew on top of the modality
    heterogeneity (0 = IID equal shards, the paper's §VI setting).
    ``omega`` is a scalar ratio, a per-modality mapping, or a sequence in
    sorted-modality order (see ``normalize_omegas``/``missing_masks``)."""
    rng = np.random.default_rng(seed)
    if dirichlet_alpha > 0:
        shards = _dirichlet_shards(ds, K, dirichlet_alpha, rng)
    else:
        idx = rng.permutation(len(ds))
        shards = np.array_split(idx, K)
    all_mods = sorted(ds.features.keys())
    miss = missing_masks(K, normalize_omegas(omega, all_mods), rng)
    missing: Dict[str, set] = {
        m: set(np.flatnonzero(miss[i])) for i, m in enumerate(all_mods)}

    clients = []
    for k in range(K):
        mods = tuple(m for m in all_mods if k not in missing[m])
        assert mods, "client lost every modality — lower omega"
        sub = ds.subset(shards[k])
        sub = MultimodalDataset(
            ds.name, {m: sub.features[m] for m in mods}, sub.labels,
            ds.n_classes)
        clients.append(ClientData(sub, mods))
    return clients


def train_test_split(ds: MultimodalDataset, test_frac: float = 0.2,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    n_test = int(test_frac * len(ds))
    return ds.subset(idx[n_test:]), ds.subset(idx[:n_test])
