"""Client partitioning with modality heterogeneity (§VI "Datasets").

The paper quantifies modality heterogeneity by a missing-modality ratio ω:
ω_m = 0.3 means 30% of clients lack modality m.  We split the dataset into K
equal-ish client shards and remove each modality from a disjoint ⌊ωK⌋-sized
client subset (disjoint so every client keeps at least one modality, matching
Fig. 1 where client 1 lacks image but keeps audio).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .synthetic import MultimodalDataset


@dataclasses.dataclass
class ClientData:
    dataset: MultimodalDataset          # only this client's modalities
    modalities: Tuple[str, ...]

    @property
    def size(self) -> int:
        return len(self.dataset)


@dataclasses.dataclass
class StackedClients:
    """Dense client-major stack of an entire cohort, for the batched round
    engine (fl/runtime.py).

    Every modality is materialised for every client at a fixed ``max_batch``
    (the largest client shard), so one jitted ``vmap`` can sweep the whole
    cohort without ragged shapes:

    * ``features[m]`` — [K, max_batch, ...] float32, zero-padded; a client
      that lacks modality m gets an all-zero block (masked out of the loss
      by ``has_modality``).
    * ``labels`` / ``sample_mask`` — [K, max_batch]; ``sample_mask[k, i]`` is
      1.0 for the ``sizes[k]`` real samples and 0.0 for padding.
    * ``has_modality[m]`` — bool [K], client-owns-modality mask.

    Built once per cohort (experiment init) and kept device-resident.
    """
    features: Dict[str, np.ndarray]
    labels: np.ndarray
    sample_mask: np.ndarray
    has_modality: Dict[str, np.ndarray]
    sizes: np.ndarray
    modalities: Tuple[str, ...]

    @property
    def K(self) -> int:
        return len(self.sizes)

    @property
    def max_batch(self) -> int:
        return self.labels.shape[1]


def stack_clients(clients: Sequence[ClientData],
                  all_modalities: Sequence[str]) -> StackedClients:
    """Pad + stack a list of per-client shards into a StackedClients."""
    K = len(clients)
    N = max(c.size for c in clients)
    labels = np.zeros((K, N), np.int32)
    smask = np.zeros((K, N), np.float32)
    has = {m: np.array([m in c.modalities for c in clients])
           for m in all_modalities}
    feats: Dict[str, np.ndarray] = {}
    for m in all_modalities:
        owners = np.flatnonzero(has[m])
        assert owners.size, f"no client owns modality {m!r}"
        shape = clients[owners[0]].dataset.features[m].shape[1:]
        feats[m] = np.zeros((K, N) + shape, np.float32)
    for k, c in enumerate(clients):
        n = c.size
        labels[k, :n] = c.dataset.labels
        smask[k, :n] = 1.0
        for m in c.modalities:
            feats[m][k, :n] = c.dataset.features[m]
    sizes = np.array([c.size for c in clients], np.int64)
    return StackedClients(feats, labels, smask, has, sizes,
                          tuple(all_modalities))


def _dirichlet_shards(ds: MultimodalDataset, K: int, alpha: float,
                      rng) -> List[np.ndarray]:
    """Label-skewed shards: per-class proportions ~ Dirichlet(alpha).
    Small alpha = strong non-IID (the data-heterogeneity regime of the
    paper's companion line of work [15])."""
    shards: List[list] = [[] for _ in range(K)]
    for c in range(ds.n_classes):
        idx_c = np.flatnonzero(ds.labels == c)
        rng.shuffle(idx_c)
        p = rng.dirichlet([alpha] * K)
        cuts = (np.cumsum(p) * len(idx_c)).astype(int)[:-1]
        for k, part in enumerate(np.split(idx_c, cuts)):
            shards[k].extend(part.tolist())
    # rebalance BEFORE materialising so donated samples move, not duplicate
    for k in range(K):
        if not shards[k]:                     # guarantee non-empty clients
            donor = int(np.argmax([len(x) for x in shards]))
            shards[k].append(shards[donor].pop())
    return [np.asarray(s, int) for s in shards]


def partition(ds: MultimodalDataset, K: int, omega: float,
              seed: int = 0,
              dirichlet_alpha: float = 0.0) -> List[ClientData]:
    """``dirichlet_alpha > 0`` adds label skew on top of the modality
    heterogeneity (0 = IID equal shards, the paper's §VI setting)."""
    rng = np.random.default_rng(seed)
    if dirichlet_alpha > 0:
        shards = _dirichlet_shards(ds, K, dirichlet_alpha, rng)
    else:
        idx = rng.permutation(len(ds))
        shards = np.array_split(idx, K)
    all_mods = sorted(ds.features.keys())
    n_missing = int(np.floor(omega * K))

    # disjoint missing sets per modality
    order = rng.permutation(K)
    missing: Dict[str, set] = {}
    c = 0
    for m in all_mods:
        missing[m] = set(order[c:c + n_missing])
        c += n_missing
        if c + n_missing > K:                       # wrap around if ω large
            c = 0

    clients = []
    for k in range(K):
        mods = tuple(m for m in all_mods if k not in missing[m])
        assert mods, "client lost every modality — lower omega"
        sub = ds.subset(shards[k])
        sub = MultimodalDataset(
            ds.name, {m: sub.features[m] for m in mods}, sub.labels,
            ds.n_classes)
        clients.append(ClientData(sub, mods))
    return clients


def train_test_split(ds: MultimodalDataset, test_frac: float = 0.2,
                     seed: int = 0):
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(ds))
    n_test = int(test_frac * len(ds))
    return ds.subset(idx[n_test:]), ds.subset(idx[:n_test])
