"""Scenario library — FedMultimodal-style heterogeneity axes as data.

The paper's experiments fix one data regime (synthetic IEMOCAP/CREMA-like,
uniform IID shards, one scalar ω).  Real federated deployments differ along
several independent axes, which the FedMultimodal benchmark suite names
precisely: how clients are *split* (natural speaker/device groups vs
Dirichlet-α label skew vs IID), which modalities each client *has* (per-
modality missingness), and how *corrupted* the features are (noise,
erasure, test-time missing modalities).  ``ScenarioSpec`` freezes one point
of that product space; ``build_scenario`` materialises it as a vectorized
``ClientStore`` + held-out test split; ``stack_scenarios`` stacks many specs
into the ``(overrides, stores, test sets)`` triple that
``FusedRoundEngine.scan_scenario_grid`` sweeps as ONE sharded device
program — a scenario *zoo* instead of a V-line.

Everything is built on the corrected ``data/partition.py`` substrate
(``missing_masks``: every client keeps ≥1 modality, every modality keeps
≥1 owner, for any feasible per-modality ω_m), with pure array ops — no
per-client Python loops — so zoo rows scale to population-sized K.

Grid rows must share K, n_per_client, the modality set and feature shapes
(one compiled program sweeps the grid); everything else — split law, ω_m
vectors, SNRs, corruption, V, seeds — varies freely per row.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from ..core import aggregation as agg
from ..models.config import FL_ARCHS
from ..wireless.cost import population_costs
from .partition import (ClientStore, missing_counts, missing_masks,
                        normalize_omegas)

#: feature shapes + class counts of the synthetic stand-in corpora
#: (data/synthetic.py) — the shapes ``PaperModelAdapter`` builds models for
DATASET_SHAPES = {"iemocap": ({"audio": (32, 11), "text": (24, 100)}, 10),
                  "crema_d": ({"audio": (32, 11), "image": (32, 32, 3)}, 6)}

SPLIT_LAWS = ("iid", "dirichlet", "natural")


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """One frozen point of the scenario product space.

    * ``split`` — client split law: ``"iid"`` (uniform label draws),
      ``"dirichlet"`` (per-client class distribution ~ Dir(α·1), small α =
      strong label skew), ``"natural"`` (``n_groups`` speaker/device groups:
      group-level Dir(α) label distributions plus a per-group feature offset
      of scale ``group_sigma`` — clients in a group look alike, the
      FedMultimodal natural-split regime);
    * ``omega`` / ``snr`` — per-modality missing ratio and class-signal
      scale; scalar, mapping or sorted-modality-order sequence (broadcast
      rules of ``data.partition.normalize_omegas``);
    * corruption — ``noise_sigma`` adds feature noise, ``erasure_rate``
      zeroes whole (client, sample, modality) feature blocks (sensor
      dropouts that still carry Eq.-12 weight), ``test_missing`` zeroes one
      modality of the *test* split (deployment-time missing sensor);
    * ``V`` — the Lyapunov drift penalty: the old V-grid is just this field
      varying across rows;
    * ``arch`` — the model-family axis (``models.config.FL_ARCHS``):
      ``"lstm-cnn"`` (the paper's submodels) or a transformer/SSD encoder
      stack (``fl.client.make_adapter``).  Param pytrees differ per arch,
      so one compiled sweep covers one arch — grid rows must agree
      (Table 3 × {lstm-cnn, transformer, ssd} is three stacked grids).
    """
    name: str = ""
    dataset: str = "iemocap"
    K: int = 10
    n_per_client: int = 8
    n_test: int = 128
    split: str = "iid"
    alpha: float = 0.5
    n_groups: int = 4
    group_sigma: float = 1.0
    omega: object = 0.3
    snr: object = 1.0
    noise_sigma: float = 0.0
    erasure_rate: float = 0.0
    test_missing: Optional[str] = None
    V: float = 1.0
    seed: int = 0
    arch: str = "lstm-cnn"

    def __post_init__(self):
        if self.dataset not in DATASET_SHAPES:
            raise ValueError(f"unknown dataset {self.dataset!r}; "
                             f"choose from {sorted(DATASET_SHAPES)}")
        if self.arch not in FL_ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}; "
                             f"choose from {FL_ARCHS}")
        if self.split not in SPLIT_LAWS:
            raise ValueError(f"unknown split {self.split!r}; "
                             f"choose from {SPLIT_LAWS}")
        if self.split != "iid" and not self.alpha > 0:
            raise ValueError(f"split={self.split!r} needs alpha > 0")
        if self.split == "natural" and self.n_groups < 1:
            raise ValueError("natural split needs n_groups >= 1")
        if not 0.0 <= self.erasure_rate <= 1.0:
            raise ValueError("erasure_rate must lie in [0, 1]")
        mods = self.modalities
        if self.test_missing is not None and self.test_missing not in mods:
            raise ValueError(f"test_missing={self.test_missing!r} is not a "
                             f"{self.dataset} modality {mods}")
        # normalize omega/snr to per-modality tuples up front so invalid
        # specs fail at construction, not mid-sweep
        object.__setattr__(self, "omega",
                           normalize_omegas(self.omega, mods))
        object.__setattr__(self, "snr", normalize_omegas(self.snr, mods))
        missing_counts(self.K, self.omega)      # range / feasibility check

    @property
    def modalities(self) -> Tuple[str, ...]:
        return tuple(sorted(DATASET_SHAPES[self.dataset][0]))

    @property
    def n_classes(self) -> int:
        return DATASET_SHAPES[self.dataset][1]

    def label(self) -> str:
        if self.name:
            return self.name
        om = "/".join(f"{w:g}" for w in self.omega)
        bits = [self.split, f"om={om}", f"V={self.V:g}"]
        if self.arch != "lstm-cnn":
            bits.append(self.arch)
        if self.noise_sigma:
            bits.append(f"noise={self.noise_sigma:g}")
        if self.erasure_rate:
            bits.append(f"erase={self.erasure_rate:g}")
        if self.test_missing:
            bits.append(f"no-{self.test_missing}")
        return ",".join(bits)


def _smooth(protos: np.ndarray) -> np.ndarray:
    """Two-pass smoothing along the leading feature axis (the temporal /
    spatial axis of every modality here), as data/synthetic.py does, so
    sequence models can integrate evidence."""
    for _ in range(2):
        protos[:, 1:] = 0.5 * (protos[:, 1:] + protos[:, :-1])
    return protos


def _sample_labels(rng, p: np.ndarray, n: int) -> np.ndarray:
    """Vectorized categorical draws: one row of ``p`` [R, C] per row of the
    output [R, n]."""
    cdf = np.cumsum(p, axis=-1)
    u = rng.random((p.shape[0], n))
    return np.minimum((u[..., None] > cdf[:, None, :]).sum(-1),
                      p.shape[1] - 1).astype(np.int32)


def build_scenario(spec: ScenarioSpec, params):
    """Materialise one spec → ``(ClientStore, test_features, test_labels)``.

    Pure array ops throughout (no per-client loops); ``params`` is the
    ``WirelessParams`` whose Eqs. 15-18 fill the store's cost vectors
    (``wireless.cost.population_costs`` over the ownership masks).  The rng
    draw order is fixed (masks → label laws → labels → per-modality protos/
    noise/corruption) so a spec is a complete, reproducible description."""
    from ..wireless.params import MODALITY_PROFILES

    shapes, C = DATASET_SHAPES[spec.dataset]
    mods = spec.modalities
    K, n, nt = spec.K, spec.n_per_client, spec.n_test
    rng = np.random.default_rng(spec.seed)

    miss = missing_masks(K, spec.omega, rng)
    has = {m: ~miss[i] for i, m in enumerate(mods)}

    groups = (np.arange(K) * spec.n_groups) // K    # contiguous blocks
    if spec.split == "iid":
        labels = rng.integers(0, C, (K, n)).astype(np.int32)
    elif spec.split == "dirichlet":
        p = rng.dirichlet([spec.alpha] * C, size=K)
        labels = _sample_labels(rng, p, n)
    else:                                           # natural groups
        p_g = rng.dirichlet([spec.alpha] * C, size=spec.n_groups)
        labels = _sample_labels(rng, p_g[groups], n)
    test_labels = rng.integers(0, C, nt).astype(np.int32)

    feats: Dict[str, np.ndarray] = {}
    test_feats: Dict[str, np.ndarray] = {}
    snrs = dict(zip(mods, spec.snr))
    for m in mods:
        shape = tuple(shapes[m])
        protos = _smooth(rng.standard_normal((C,) + shape).astype(np.float32))
        x = (protos[labels] * np.float32(snrs[m])
             + rng.standard_normal((K, n) + shape).astype(np.float32))
        if spec.split == "natural" and spec.group_sigma:
            offs = rng.standard_normal(
                (spec.n_groups,) + shape).astype(np.float32)
            x = x + np.float32(spec.group_sigma) * offs[groups][:, None]
        if spec.noise_sigma:
            x = x + np.float32(spec.noise_sigma) * rng.standard_normal(
                x.shape).astype(np.float32)
        if spec.erasure_rate:
            erased = rng.random((K, n)) < spec.erasure_rate
            x = np.where(erased[(...,) + (None,) * len(shape)], 0.0, x)
        own = has[m].reshape((K,) + (1,) * (len(shape) + 1))
        feats[m] = (x * own).astype(np.float32)
        # held-out split: clean draws from the same prototypes (corruption
        # models the *clients'* sensors), except a deployment-time missing
        # modality, which zeroes the whole test block
        tx = (protos[test_labels] * np.float32(snrs[m])
              + rng.standard_normal((nt,) + shape).astype(np.float32))
        if spec.test_missing == m:
            tx = np.zeros_like(tx)
        test_feats[m] = tx.astype(np.float32)

    cost = population_costs(has, mods, np.full(K, float(n)),
                            MODALITY_PROFILES[spec.dataset], params)
    store = ClientStore(
        feats, labels, np.ones((K, n), np.float32), has,
        np.full(K, float(n), np.float32),
        cost.gamma_bits.astype(np.float32),
        cost.tau_cmp.astype(np.float32),
        cost.e_cmp.astype(np.float32), mods)
    return store, test_feats, test_labels


def scenario_overrides(store: ClientStore, params, V: float) -> dict:
    """The per-scenario solver-data row ``scan_scenario_grid`` consumes:
    every template entry that depends on the scenario's population —
    ownership, Eq. 12 weight denominators, Eqs. 15-18 costs — plus its V."""
    mods = store.modalities
    has = np.stack([np.asarray(store.has_modality[m], bool) for m in mods])
    sizes = np.asarray(store.sizes, np.float64)
    wbar = agg.stacked_weights(sizes, {m: has[i] for i, m in
                                       enumerate(mods)})
    tau_cmp = np.asarray(store.tau_cmp, np.float64)
    return {"V": np.float64(V),
            "gamma": np.asarray(store.gamma_bits, np.float64),
            "tau_rem": params.tau_max - tau_cmp,
            "tau_cmp": tau_cmp,
            "e_cmp": np.asarray(store.e_cmp, np.float64),
            "has": has, "D": sizes,
            "wbar": np.stack([wbar[m] for m in mods])}


class ScenarioGrid(NamedTuple):
    """Stacked zoo: leaves carry a leading [S] scenario axis."""
    overrides: dict                 # solver-data rows (scan_scenario_grid)
    stores: ClientStore             # [S]-leading ClientStore leaves
    test_features: Dict[str, np.ndarray]
    test_labels: np.ndarray         # [S, n_test]
    specs: Tuple[ScenarioSpec, ...]

    @property
    def n(self) -> int:
        return len(self.specs)

    def store_row(self, s: int) -> ClientStore:
        """Scenario ``s``'s un-stacked store (e.g. to seed an engine)."""
        import jax
        return jax.tree.map(lambda x: x[s], self.stores)


def stack_scenarios(specs: Sequence[ScenarioSpec], params) -> ScenarioGrid:
    """Build + stack a zoo.  All specs must agree on dataset geometry
    (K, n_per_client, n_test, modality set) — one compiled sweep covers the
    grid; the heterogeneity axes vary per row."""
    import jax

    specs = tuple(specs)
    if not specs:
        raise ValueError("empty scenario grid")
    s0 = specs[0]
    for s in specs[1:]:
        same = (s.dataset == s0.dataset and s.K == s0.K
                and s.n_per_client == s0.n_per_client
                and s.n_test == s0.n_test and s.arch == s0.arch)
        if not same:
            raise ValueError(
                f"grid rows must share dataset/K/n_per_client/n_test/arch; "
                f"{s.label()!r} differs from {s0.label()!r}")
    built = [build_scenario(s, params) for s in specs]
    stores = jax.tree.map(lambda *xs: np.stack(xs),
                          *[b[0] for b in built])
    test_feats = {m: np.stack([b[1][m] for b in built])
                  for m in s0.modalities}
    test_labels = np.stack([b[2] for b in built])
    rows = [scenario_overrides(b[0], params, s.V)
            for b, s in zip(built, specs)]
    overrides = {k: np.stack([r[k] for r in rows]) for k in rows[0]}
    return ScenarioGrid(overrides, stores, test_feats, test_labels, specs)
