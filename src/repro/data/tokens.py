"""Synthetic token-stream pipeline for the LM-scale architectures.

Deterministic, seekable synthetic corpus: a mixture of Zipfian unigrams and a
repeated-ngram process so the LM loss actually decreases during the example
training runs.  Batches are produced host-side as numpy and fed to jit'd
steps; shape = what ``input_specs`` declares for the dry-run.
"""
from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.2):
        self.vocab = vocab_size
        self.rng = np.random.default_rng(seed)
        ranks = np.arange(1, min(vocab_size, 50000) + 1, dtype=np.float64)
        p = ranks ** (-zipf_a)
        self.p = p / p.sum()
        self.support = len(ranks)

    def batch(self, batch_size: int, seq_len: int) -> dict:
        toks = self.rng.choice(self.support, size=(batch_size, seq_len + 1),
                               p=self.p).astype(np.int32)
        # inject copyable structure: repeat a prefix window later in the seq
        if seq_len >= 64:
            w = 16
            start = self.rng.integers(0, seq_len // 2)
            dst = self.rng.integers(seq_len // 2, seq_len - w)
            toks[:, dst:dst + w] = toks[:, start:start + w]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def vlm_batch(rng: np.random.Generator, batch: int, seq: int, n_patches: int,
              d_patch: int, vocab: int) -> dict:
    toks = rng.integers(0, min(vocab, 50000), size=(batch, seq + 1),
                        dtype=np.int32)
    patches = rng.normal(size=(batch, n_patches, d_patch)).astype(np.float32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:], "patches": patches}
