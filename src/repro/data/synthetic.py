"""Synthetic multimodal datasets standing in for CREMA-D / IEMOCAP.

The real corpora are not available offline (repro gate, DESIGN.md §2).  We
generate classification data whose *structure* matches the paper's setup:

* crema_like  — audio [T=32, 11] sequences + image [32, 32, 3], 6 classes.
* iemocap_like — audio [T=32, 11] + text [T=24, 100] sequences, 10 classes.

Each modality draws class-conditional patterns with a modality-specific SNR;
audio gets the highest SNR so the audio submodel converges fastest — the
modality-imbalance phenomenon (§VI-B: "the audio submodel converges faster
than the image submodel") that JCSBA's Theorem-1 term exploits.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import numpy as np


@dataclasses.dataclass
class MultimodalDataset:
    name: str
    features: Dict[str, np.ndarray]     # modality -> [N, ...] float32
    labels: np.ndarray                  # [N] int32
    n_classes: int

    def __len__(self) -> int:
        return len(self.labels)

    def subset(self, idx) -> "MultimodalDataset":
        return MultimodalDataset(
            self.name, {m: x[idx] for m, x in self.features.items()},
            self.labels[idx], self.n_classes)


def _seq_modality(rng, labels, T, d, n_classes, snr):
    """Class-dependent temporal pattern + noise. [N, T, d]."""
    N = len(labels)
    protos = rng.normal(size=(n_classes, T, d)).astype(np.float32)
    # smooth prototypes along time so an LSTM can integrate evidence
    for _ in range(2):
        protos[:, 1:] = 0.5 * (protos[:, 1:] + protos[:, :-1])
    x = protos[labels] * snr + rng.normal(size=(N, T, d)).astype(np.float32)
    return x.astype(np.float32)


def _img_modality(rng, labels, hw, n_classes, snr):
    N = len(labels)
    protos = rng.normal(size=(n_classes, hw, hw, 3)).astype(np.float32)
    for _ in range(3):                                   # spatial smoothing
        protos[:, 1:] = 0.5 * (protos[:, 1:] + protos[:, :-1])
        protos[:, :, 1:] = 0.5 * (protos[:, :, 1:] + protos[:, :, :-1])
    x = protos[labels] * snr + rng.normal(size=(N, hw, hw, 3)).astype(np.float32)
    return x.astype(np.float32)


def crema_like(seed: int = 0, n: int = 1200,
               snr: Tuple[float, float] = (1.2, 1.0)) -> MultimodalDataset:
    """Audio converges fast (high SNR); image is the slow modality."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 6, n).astype(np.int32)
    return MultimodalDataset(
        "crema_d",
        {"audio": _seq_modality(rng, labels, 32, 11, 6, snr[0]),
         "image": _img_modality(rng, labels, 32, 6, snr[1])},
        labels, 6)


def iemocap_like(seed: int = 0, n: int = 1200,
                 snr: Tuple[float, float] = (1.2, 0.9)) -> MultimodalDataset:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, n).astype(np.int32)
    return MultimodalDataset(
        "iemocap",
        {"audio": _seq_modality(rng, labels, 32, 11, 10, snr[0]),
         "text": _seq_modality(rng, labels, 24, 100, 10, snr[1])},
        labels, 10)


DATASETS = {"crema_d": crema_like, "iemocap": iemocap_like}
