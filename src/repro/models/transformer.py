"""Decoder-only LM supporting dense / MoE / hybrid / SSM stacks.

The layer stack is ``lax.scan`` over ``n_blocks`` repetitions of the config's
super-block (cf. ``ModelConfig.block_pattern``), with per-block params stacked
on a leading axis — HLO size stays constant in depth, which keeps the 512-device
dry-run compiles tractable.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .config import LayerSpec, ModelConfig
from . import layers as L
from .moe import init_moe, moe_apply
from .mamba2 import (init_mamba, init_mamba_cache, mamba_decode, mamba_fwd,
                     mamba_prefill)


# ----------------------------------------------------------------------------
# per-layer init / apply
# ----------------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    k1, k2 = jax.random.split(key)
    dt = cfg.param_dtype
    p = {"norm1": jnp.zeros((cfg.d_model,), dt)}
    if spec.kind == "attn":
        p["mixer"] = L.init_attention(k1, cfg)
    else:
        p["mixer"] = init_mamba(k1, cfg)
    if spec.moe:
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        p["ffn"] = init_moe(k2, cfg)
    elif cfg.d_ff > 0:
        p["norm2"] = jnp.zeros((cfg.d_model,), dt)
        p["ffn"] = L.init_mlp(k2, cfg)
    return p


def apply_layer(p, x, cfg: ModelConfig, spec: LayerSpec, *, n_groups: int = 1,
                attn_chunk: int = 1024, impl: str = "xla"):
    aux = jnp.zeros((), jnp.float32)
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        h = L.attention_fwd(p["mixer"], h, cfg, window=spec.window,
                            chunk=attn_chunk, impl=impl)
    else:
        h = mamba_fwd(p["mixer"], h, cfg, impl=impl)
    x = x + h
    if "ffn" in p:
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.moe:
            h, aux = moe_apply(p["ffn"], h, cfg, n_groups=n_groups)
        else:
            h = L.mlp(p["ffn"], h)
        x = x + h
    return x, aux


def init_layer_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, seq: int,
                     dtype):
    if spec.kind == "attn":
        return L.init_attn_cache(cfg, batch, seq, spec.window, dtype)
    return init_mamba_cache(cfg, batch, dtype)


def apply_layer_prefill(p, x, cache, cfg: ModelConfig, spec: LayerSpec, *,
                        n_groups: int = 1, attn_chunk: int = 1024):
    """Training-math forward over the whole prompt that also fills this
    layer's decode cache (attn: ring-slot K/V scatter; mamba: conv tails +
    final SSD state).  Mirrors ``apply_layer``; the FFN runs with the same
    ``n_groups`` semantics as training (decode parity of MoE capacity drops
    is a tolerance question, same as the teacher-forced path)."""
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        h, k, v = L.attention_prefill(p["mixer"], h, cfg, window=spec.window,
                                      chunk=attn_chunk)
        newc = L.fill_attn_cache(cache, k, v, seq_len=x.shape[1])
    else:
        h, newc = mamba_prefill(p["mixer"], h, cfg)
        newc = jax.tree.map(lambda n, o: n.astype(o.dtype), newc, cache)
    x = x + h
    if "ffn" in p:
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.moe:
            h, _ = moe_apply(p["ffn"], h, cfg, n_groups=n_groups)
        else:
            h = L.mlp(p["ffn"], h)
        x = x + h
    return x, newc


def apply_layer_decode(p, x, cache, index, cfg: ModelConfig, spec: LayerSpec):
    h = L.rms_norm(x, p["norm1"], cfg.norm_eps)
    if spec.kind == "attn":
        h, cache = L.attention_decode(p["mixer"], h, cache, index, cfg,
                                      window=spec.window)
    else:
        h, cache = mamba_decode(p["mixer"], h, cache, cfg)
    x = x + h
    if "ffn" in p:
        h = L.rms_norm(x, p["norm2"], cfg.norm_eps)
        if spec.moe:
            h, _ = moe_apply(p["ffn"], h, cfg, n_groups=1)
        else:
            h = L.mlp(p["ffn"], h)
        x = x + h
    return x, cache


# ----------------------------------------------------------------------------
# whole model
# ----------------------------------------------------------------------------
def init_params(key, cfg: ModelConfig):
    pattern = cfg.block_pattern()
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype

    def one_block(bk):
        bks = jax.random.split(bk, len(pattern))
        return {f"l{i}": init_layer(bks[i], cfg, spec)
                for i, spec in enumerate(pattern)}

    block_keys = jax.random.split(ks[0], cfg.n_blocks)
    blocks = jax.vmap(one_block)(block_keys)
    p = {
        "embed": (jax.random.normal(ks[1], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(ks[2], (cfg.d_model, cfg.vocab_size),
                                          jnp.float32) * 0.02).astype(dt)
    return p


def backbone(params, x, cfg: ModelConfig, *, n_groups: int = 1,
             attn_chunk: int = 1024, residual_spec=None, remat: bool = False,
             impl: str = "xla"):
    """x: [B, S, D] embeddings -> (hidden [B,S,D], moe_aux scalar).

    ``residual_spec``: optional PartitionSpec constraint re-applied to the
    residual stream after every super-block (e.g. sequence-over-model
    sharding — Megatron-style sequence parallelism; used by the §Perf
    hillclimbs).  ``remat``: activation-checkpoint each super-block.
    ``impl="pallas"``: route attention/SSD mixers through the Pallas kernels
    (differentiable — custom VJPs recompute the backward via the XLA path).
    """
    pattern = cfg.block_pattern()

    def blk(h, bp):
        aux = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(pattern):
            h, a = apply_layer(bp[f"l{i}"], h, cfg, spec, n_groups=n_groups,
                               attn_chunk=attn_chunk, impl=impl)
            aux = aux + a
        if residual_spec is not None:
            h = jax.lax.with_sharding_constraint(h, residual_spec)
        return h, aux

    if remat:
        blk = jax.checkpoint(blk)

    def scan_body(carry, bp):
        return blk(carry, bp)

    x, auxs = jax.lax.scan(scan_body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.sum(auxs)


def embed_tokens(params, tokens, cfg: ModelConfig):
    return params["embed"][tokens]


def unembed(params, h, cfg: ModelConfig):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w


def forward(params, tokens, cfg: ModelConfig, *, n_groups: int = 1,
            attn_chunk: int = 1024, **bk):
    """tokens [B,S] -> (logits [B,S,V], moe_aux)."""
    x = embed_tokens(params, tokens, cfg)
    h, aux = backbone(params, x, cfg, n_groups=n_groups,
                      attn_chunk=attn_chunk, **bk)
    return unembed(params, h, cfg), aux


def lm_loss(logits, labels, mask=None):
    """Mean next-token CE in fp32. logits [B,S,V], labels [B,S]."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return nll.mean()
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_lm_loss(params, h, labels, cfg: ModelConfig, chunk: int):
    """Fused unembed + CE over sequence chunks: the [B,S,V] logits tensor is
    never materialised — per chunk only [B,chunk,V] exists (the XLA-side
    analogue of the fusion_loss Pallas kernel's streaming pass; §Perf
    hillclimb lever for memory-bound training shapes)."""
    B, S, D = h.shape
    chunk = min(chunk, S)
    while S % chunk != 0:
        chunk //= 2
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(tot, xs):
        hh, ll = xs
        logits = unembed(params, hh, cfg)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(logits.astype(jnp.float32),
                                   ll[..., None], axis=-1)[..., 0]
        return tot + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (B * S)


def loss_fn(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
            attn_chunk: int = 1024, aux_weight: float = 0.01,
            loss_chunk: Optional[int] = None, **bk):
    if loss_chunk:
        x = embed_tokens(params, batch["tokens"], cfg)
        h, aux = backbone(params, x, cfg, n_groups=n_groups,
                          attn_chunk=attn_chunk, **bk)
        return (chunked_lm_loss(params, h, batch["labels"], cfg, loss_chunk)
                + aux_weight * aux)
    logits, aux = forward(params, batch["tokens"], cfg, n_groups=n_groups,
                          attn_chunk=attn_chunk, **bk)
    return lm_loss(logits, batch["labels"], batch.get("mask")) + aux_weight * aux


# ----------------------------------------------------------------------------
# serving: prefill + decode
# ----------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=None):
    dtype = dtype or cfg.param_dtype
    pattern = cfg.block_pattern()

    def one(spec):
        return init_layer_cache(cfg, spec, batch, seq, dtype)

    single = {f"l{i}": one(spec) for i, spec in enumerate(pattern)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_blocks,) + a.shape), single)


def decode_step(params, cache, token, index, cfg: ModelConfig):
    """token [B,1] int32; index scalar int32 (#tokens already cached).

    Returns (logits [B,1,V], new_cache).
    """
    pattern = cfg.block_pattern()
    x = embed_tokens(params, token, cfg)

    def blk(carry, inp):
        h = carry
        bp, bc = inp
        newc = {}
        for i, spec in enumerate(pattern):
            h, c = apply_layer_decode(bp[f"l{i}"], h, bc[f"l{i}"], index, cfg,
                                      spec)
            newc[f"l{i}"] = c
        return h, newc

    h, new_cache = jax.lax.scan(blk, x, (params["blocks"], cache))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return unembed(params, h, cfg), new_cache


def prefill(params, tokens, cfg: ModelConfig, *, n_groups: int = 1,
            attn_chunk: int = 1024, **bk):
    """Prefill forward: returns logits of the LAST position [B, V].

    (Cache materialisation during prefill is a serving-layer concern — cf.
    ``launch/serve.py`` which prefills then decodes; the dry-run lowers this
    function for the prefill shapes.)
    """
    x = embed_tokens(params, tokens, cfg)
    h, _ = backbone(params, x, cfg, n_groups=n_groups,
                    attn_chunk=attn_chunk, **bk)
    return unembed(params, h[:, -1:, :], cfg)[:, 0, :]


def prefill_with_cache(params, tokens, cache, cfg: ModelConfig, *,
                       n_groups: int = 1, attn_chunk: int = 1024):
    """Bulk prefill: one chunked pass over the prompt that fills the decode
    cache and returns the last position's logits.

    tokens [B,S]; ``cache`` from ``init_cache`` (stacked [n_blocks][l{i}]).
    Returns (logits [B,V], filled cache) — the cache is ready for
    ``decode_step(..., index=S)``, replacing S teacher-forced decode steps
    with a single program (``launch/serve.py``'s fast path).
    """
    pattern = cfg.block_pattern()
    x = embed_tokens(params, tokens, cfg)

    def blk(h, inp):
        bp, bc = inp
        newc = {}
        for i, spec in enumerate(pattern):
            h, c = apply_layer_prefill(bp[f"l{i}"], h, bc[f"l{i}"], cfg, spec,
                                       n_groups=n_groups,
                                       attn_chunk=attn_chunk)
            newc[f"l{i}"] = c
        return h, newc

    h, new_cache = jax.lax.scan(blk, x, (params["blocks"], cache))
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    return unembed(params, h[:, -1:, :], cfg)[:, 0, :], new_cache
