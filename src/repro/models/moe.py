"""Mixture-of-Experts layer with sort-based capacity dispatch.

Design (TPU-native, cf. DESIGN.md §4):
* Tokens are processed in ``n_groups`` groups (one group per data shard) so the
  argsort / scatter stay shard-local; expert weights are sharded over the
  ``model`` mesh axis, so the group->expert scatter is the all-to-all that shows
  up in the roofline's collective term.
* Dispatch: top-k routing, tokens sorted by expert id, capacity
  ``C = ceil(k * T_group / E * capacity_factor)``; overflow tokens are dropped
  (contribute 0) exactly as in Switch/GShard-style capacity routing.
* Router runs in fp32; an auxiliary load-balance loss (Switch-style) is
  returned for the training objective.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import init_dense


def init_moe(key, cfg: ModelConfig):
    E = cfg.n_experts
    F = cfg.expert_d_ff or cfg.d_ff
    D = cfg.d_model
    dt = cfg.param_dtype
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(D)
    p = {
        "router": (jax.random.normal(ks[0], (D, E), jnp.float32) * scale).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (E, D, F), jnp.float32) * scale).astype(dt),
        "wu": (jax.random.normal(ks[2], (E, D, F), jnp.float32) * scale).astype(dt),
        "wd": (jax.random.normal(ks[3], (E, F, D), jnp.float32) / math.sqrt(F)).astype(dt),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": init_dense(kss[0], D, Fs, dt),
            "wu": init_dense(kss[1], D, Fs, dt),
            "wd": init_dense(kss[2], Fs, D, dt),
        }
    return p


def _dispatch_group(x, logits, k: int, capacity: int):
    """x: [T, D]; logits: [T, E] fp32. Returns (y [T, D], aux fp32)."""
    T, D = x.shape
    E = logits.shape[-1]
    probs = jax.nn.softmax(logits, axis=-1)                    # fp32
    gates, idx = jax.lax.top_k(probs, k)                       # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                                   # [T*k]
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                       # exclusive cumsum
    pos = jnp.arange(T * k) - starts[se]                       # rank within expert
    keep = pos < capacity
    dest = jnp.where(keep, se * capacity + pos, E * capacity)  # drop row -> scratch

    xe = jnp.zeros((E * capacity + 1, D), x.dtype).at[dest].add(x[st])
    xe = xe[: E * capacity].reshape(E, capacity, D)
    return (xe, se, st, sg, keep, dest, counts, probs)


def moe_apply(p, x, cfg: ModelConfig, *, n_groups: int = 1):
    """x: [B, S, D] -> (y [B, S, D], aux_loss scalar fp32)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    assert T % n_groups == 0, (T, n_groups)
    Tg = T // n_groups
    capacity = max(int(math.ceil(k * Tg / E * cfg.capacity_factor)), 1)

    xf = x.reshape(n_groups, Tg, D)
    logits = (xf.astype(jnp.float32) @ p["router"][None]).astype(jnp.float32)

    def per_group(xg, lg):
        xe, se, st, sg, keep, dest, counts, probs = _dispatch_group(xg, lg, k, capacity)
        h = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
        u = jnp.einsum("ecd,edf->ecf", xe, p["wu"])
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["wd"])
        yf = y.reshape(E * capacity, D)
        contrib = yf[jnp.minimum(dest, E * capacity - 1)] * \
            (sg * keep.astype(jnp.float32))[:, None].astype(y.dtype)
        out = jnp.zeros((Tg, D), y.dtype).at[st].add(contrib)
        # Switch-style load balance: E * sum_e f_e * P_e
        frac = counts.astype(jnp.float32) / (Tg * k)
        pmean = probs.mean(axis=0)
        aux = E * jnp.sum(frac * pmean)
        return out, aux

    y, aux = jax.vmap(per_group)(xf, logits)
    y = y.reshape(B, S, D)
    if "shared" in p:
        from .layers import mlp
        y = y + mlp(p["shared"], x)
    return y, aux.mean()
