"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU MLP.

Attention notes
---------------
* Training/prefill uses a *query-chunked, online-softmax* ("flash-style")
  attention written in pure jnp + ``lax.scan`` so the S x S score matrix is never
  materialised — this is the XLA path used by the multi-pod dry-run.  The Pallas
  TPU kernel in ``repro.kernels.flash_attention`` implements the same math with
  explicit VMEM BlockSpecs and is validated against ``ref.py`` in interpret mode.
* Sliding-window layers (gemma3 locals) slice only the ``window + chunk`` keys a
  query chunk can see, so local attention is genuinely sub-quadratic.
* Decode attends one query token against a KV cache (ring buffer for windowed
  layers).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

NEG_INF = -1e30


# ----------------------------------------------------------------------------
# basics
# ----------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False):
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) / math.sqrt(d_in)
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ----------------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------------
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B, S, N, hd]; positions: [B, S] (int32)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# attention parameters
# ----------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig):
    hd, H, K, D = cfg.hd, cfg.n_heads, cfg.n_kv_heads, cfg.d_model
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p = {
        "wq": init_dense(ks[0], D, H * hd, dt, bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], D, K * hd, dt, bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], D, K * hd, dt, bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], H * hd, D, dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype=dt)
        p["k_norm"] = jnp.zeros((hd,), dtype=dt)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions):
    B, S, _ = x.shape
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = dense(p["wq"], x).reshape(B, S, H, hd)
    k = dense(p["wk"], x).reshape(B, S, K, hd)
    v = dense(p["wv"], x).reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ----------------------------------------------------------------------------
# flash-style chunked attention (pure jnp; never materialises S x S)
# ----------------------------------------------------------------------------
def _attn_chunk(q, k, v, mask, scale):
    """q: [B,G,R,Cq,hd]  k/v: [B,G,Sk,hd]  mask: [Cq,Sk] -> out [B,G,R,Cq,hd].

    G = kv head groups, R = q heads per kv head.  fp32 softmax.
    """
    s = jnp.einsum("bgrqh,bgkh->bgrqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bgrqk,bgkh->bgrqh", (e / jnp.maximum(z, 1e-30)).astype(v.dtype), v)
    return o


def chunked_attention(q, k, v, *, window: Optional[int], chunk: int = 1024,
                      q_offset: int = 0, causal: bool = True) -> jax.Array:
    """Causal (optionally sliding-window) attention.

    q: [B, Sq, H, hd], k/v: [B, Sk, K, hd].  Returns [B, Sq, H, hd].
    ``q_offset`` is the absolute position of q[0] relative to k[0]
    (prefill: 0; chunked decode not used here).
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    R = H // K
    scale = 1.0 / math.sqrt(hd)
    chunk = min(chunk, Sq)
    while Sq % chunk != 0:          # self-adjust to a divisor of Sq
        chunk //= 2
    n_chunks = Sq // chunk

    qg = q.reshape(B, Sq, K, R, hd).transpose(0, 2, 3, 1, 4)   # [B,K,R,Sq,hd]
    kg = k.transpose(0, 2, 1, 3)                               # [B,K,Sk,hd]
    vg = v.transpose(0, 2, 1, 3)

    if window is None:
        # full attention: causal -> each q chunk sees keys [0, t0 + chunk);
        # bidirectional (encoder / cross-attn) -> all keys.
        def body(t, _):
            t0 = t * chunk
            qc = jax.lax.dynamic_slice_in_dim(qg, t0, chunk, axis=3)
            qpos = q_offset + t0 + jnp.arange(chunk)
            kpos = jnp.arange(Sk)
            if causal:
                mask = kpos[None, :] <= qpos[:, None]
            else:
                mask = jnp.ones((chunk, Sk), bool)
            o = _attn_chunk(qc, kg, vg, mask, scale)
            return t + 1, o
        _, outs = jax.lax.scan(body, 0, None, length=n_chunks)
    else:
        # sliding window: q chunk [t0, t0+chunk) sees keys [t0-window+1, t0+chunk)
        w = window
        pad = ((0, 0), (0, 0), (w, 0), (0, 0))
        kp = jnp.pad(kg, pad)
        vp = jnp.pad(vg, pad)
        span = w + chunk

        def body(t, _):
            t0 = t * chunk
            qc = jax.lax.dynamic_slice_in_dim(qg, t0, chunk, axis=3)
            kc = jax.lax.dynamic_slice_in_dim(kp, t0, span, axis=2)
            vc = jax.lax.dynamic_slice_in_dim(vp, t0, span, axis=2)
            qpos = q_offset + t0 + jnp.arange(chunk)
            kpos = q_offset + t0 - w + jnp.arange(span)
            mask = (kpos[None, :] <= qpos[:, None]) & \
                   (kpos[None, :] > qpos[:, None] - w) & (kpos[None, :] >= 0)
            o = _attn_chunk(qc, kc, vc, mask, scale)
            return t + 1, o
        _, outs = jax.lax.scan(body, 0, None, length=n_chunks)

    # outs: [n_chunks, B, K, R, chunk, hd] -> [B, Sq, H, hd]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out


# ----------------------------------------------------------------------------
# Pallas-backed causal attention with an XLA-recompute backward.  The TPU
# kernel (repro.kernels.flash_attention, interpret mode off-TPU) has no
# backward kernel, so ``pallas_attention`` pairs the kernel forward with a
# custom VJP that replays the bit-matching chunked-jnp path under ``jax.vjp``
# — gradients are exactly the XLA path's (the two forwards agree in fp32,
# tests/test_kernels.py), which is what lets the FL backbone adapter put the
# kernel on the *training* hot path (fl/client.py, attention_impl="pallas").
# ----------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def pallas_attention(q, k, v, window: Optional[int], chunk: int):
    """Causal attention via the flash-attention kernel; layouts as
    ``chunked_attention`` (q [B,Sq,H,hd], k/v [B,Sk,K,hd])."""
    from ..kernels.flash_attention.ops import flash_attention
    return flash_attention(q, k, v, causal=True, window=window)


def _pallas_attention_fwd(q, k, v, window, chunk):
    return pallas_attention(q, k, v, window, chunk), (q, k, v)


def _pallas_attention_bwd(window, chunk, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: chunked_attention(q_, k_, v_, window=window,
                                             chunk=chunk), q, k, v)
    return vjp(g)


pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def attention_prefill(p, x, cfg: ModelConfig, *, window: Optional[int],
                      positions=None, chunk: int = 1024, impl: str = "xla"):
    """Prefill attention layer that also exports the post-RoPE K/V for the
    decode cache.  x: [B,S,D] -> (y [B,S,D], k [B,S,K,hd], v [B,S,K,hd]) —
    the K/V are exactly what S teacher-forced decode steps would have
    written (``attention_decode`` caches post-``_project_qkv`` tensors).
    ``impl="pallas"`` routes the score/softmax/value contraction through the
    flash-attention kernel (``pallas_attention`` above)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = _project_qkv(p, x, cfg, positions)
    if impl == "pallas":
        o = pallas_attention(q, k, v, window, min(chunk, S))
    else:
        o = chunked_attention(q, k, v, window=window, chunk=min(chunk, S))
    return dense(p["wo"], o.reshape(B, S, cfg.n_heads * cfg.hd)), k, v


def attention_fwd(p, x, cfg: ModelConfig, *, window: Optional[int],
                  positions=None, chunk: int = 1024, impl: str = "xla"):
    """Full training/prefill attention layer. x: [B,S,D] -> [B,S,D]."""
    y, _, _ = attention_prefill(p, x, cfg, window=window, positions=positions,
                                chunk=chunk, impl=impl)
    return y


def fill_attn_cache(cache: dict, k, v, *, seq_len: int) -> dict:
    """Write bulk-prefill K/V [B,S,K,hd] into a decode cache as if S decode
    steps had run: slot ``i % size`` holds position i's K/V, later positions
    overwriting earlier ones in the ring buffer — only the last
    ``min(S, size)`` positions survive, scattered at their ring slots."""
    size = cache["k"].shape[1]
    S = k.shape[1]
    L = min(S, size)
    slots = np.arange(S - L, S) % size
    return {
        "k": cache["k"].at[:, slots].set(k[:, S - L:].astype(cache["k"].dtype)),
        "v": cache["v"].at[:, slots].set(v[:, S - L:].astype(cache["v"].dtype)),
    }


# ----------------------------------------------------------------------------
# decode (single token vs KV cache)
# ----------------------------------------------------------------------------
def init_attn_cache(cfg: ModelConfig, batch: int, seq: int,
                    window: Optional[int], dtype) -> dict:
    size = seq if window is None else min(window, seq)
    K, hd = cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((batch, size, K, hd), dtype=dtype),
        "v": jnp.zeros((batch, size, K, hd), dtype=dtype),
    }


def attention_decode(p, x, cache: dict, index: jax.Array, cfg: ModelConfig,
                     *, window: Optional[int]):
    """x: [B,1,D]; index: scalar int32 = number of tokens already in cache.

    Returns (y [B,1,D], new_cache).
    """
    B = x.shape[0]
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    R = H // K
    pos = jnp.broadcast_to(index[None, None].astype(jnp.int32), (B, 1))
    q, k, v = _project_qkv(p, x, cfg, pos)          # q [B,1,H,hd]; k/v [B,1,K,hd]
    size = cache["k"].shape[1]
    slot = (index % size).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)

    kpos = jnp.arange(size)
    if window is None:
        valid = kpos <= index                        # positions written so far
    else:
        # ring buffer: entry at slot s holds absolute position p with p % size == s
        # valid if within the last `window` tokens (incl. the new one)
        abs_pos = kpos + ((index - kpos) // size) * size
        abs_pos = jnp.where(abs_pos > index, abs_pos - size, abs_pos)
        valid = (abs_pos >= 0) & (abs_pos >= index - size + 1) & (abs_pos <= index)
    qh = q.reshape(B, 1, K, R, hd).transpose(0, 2, 3, 1, 4)       # [B,K,R,1,hd]
    kh = ck.transpose(0, 2, 1, 3)                                 # [B,K,size,hd]
    vh = cv.transpose(0, 2, 1, 3)
    s = jnp.einsum("bgrqh,bgkh->bgrqk", qh, kh).astype(jnp.float32) / math.sqrt(hd)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w_ = jax.nn.softmax(s, axis=-1).astype(vh.dtype)
    o = jnp.einsum("bgrqk,bgkh->bgrqh", w_, vh)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H * hd)
    y = dense(p["wo"], o)
    return y, {"k": ck, "v": cv}


# ----------------------------------------------------------------------------
# MLP (SwiGLU)
# ----------------------------------------------------------------------------
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "wg": init_dense(ks[0], cfg.d_model, d_ff, dt),
        "wu": init_dense(ks[1], cfg.d_model, d_ff, dt),
        "wd": init_dense(ks[2], d_ff, cfg.d_model, dt),
    }


def mlp(p, x):
    return dense(p["wd"], jax.nn.silu(dense(p["wg"], x)) * dense(p["wu"], x))
