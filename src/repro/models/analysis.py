"""Analytic FLOPs / parameter accounting for the roofline report.

MODEL_FLOPS follows the assignment definition: 6·N·D for dense training
(N = params, D = tokens), 6·N_active·D for MoE; decode steps are forward-only:
2·N_active per generated token (plus attention's O(S) KV reads, which are
memory- not FLOP-dominated).
"""
from __future__ import annotations

import re
from typing import Tuple

import numpy as np
import jax

_EXPERT_RE = re.compile(r"ffn/(wg|wu|wd)$")


def _path_str(path) -> str:
    return "/".join(str(p.key) if hasattr(p, "key") else f"#{getattr(p, 'idx', p)}"
                    for p in path)


def param_counts(params_shape, cfg) -> Tuple[int, int]:
    """(N_total, N_active). Expert tensors [E, ., .] count k/E of their
    params as active (top-k routing); everything else is always active."""
    total = 0
    active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shape)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        p = _path_str(path)
        if _EXPERT_RE.search(p) and leaf.ndim == 3 and cfg.n_experts > 0:
            active += n * cfg.top_k // cfg.n_experts
        else:
            active += n
    return total, active


def model_flops(cfg, params_shape, shape) -> dict:
    """Assignment-standard MODEL_FLOPS for one step of the given input shape."""
    n_total, n_active = param_counts(params_shape, cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = B * S
        flops = 2 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = B
        flops = 2 * n_active * tokens
    return {"n_params": int(n_total), "n_active": int(n_active),
            "tokens": int(tokens), "model_flops": int(flops)}
