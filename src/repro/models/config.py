"""Model configuration and layer-pattern derivation.

A single ``ModelConfig`` covers all six assigned architecture families
(dense / moe / hybrid / ssm / vlm / audio).  The layer stack is described by a
repeating *super-block*: ``block_pattern`` lists the per-layer kind inside one
block and the stack is ``n_layers // len(block_pattern)`` scanned repetitions.
Uniform architectures use a block of size 1.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside a super-block."""
    kind: str = "attn"              # "attn" | "mamba"
    window: Optional[int] = None    # sliding-window size (None = full/causal)
    moe: bool = False               # MoE MLP instead of dense MLP


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    # --- attention flavour ---
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None    # window for "local" layers
    local_global_ratio: int = 0             # gemma3: 5 => 5 local + 1 global per block
    rope_theta: float = 10000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE on layers with (i % moe_every == moe_every-1)
    expert_d_ff: Optional[int] = None       # kimi: per-expert d_ff != dense d_ff
    n_shared_experts: int = 0               # kimi-style shared expert
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    attn_every: int = 0         # jamba: one attn layer per `attn_every` layers
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # --- multimodal (decision-level fusion per the paper) ---
    modalities: Tuple[str, ...] = ("text",)
    frontend_dims: Tuple[int, ...] = ()     # stub embedding dims per extra modality
    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    # --- misc ---
    tie_embeddings: bool = False
    source: str = ""            # citation (paper / model card)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    # ------------------------------------------------------------------
    def block_pattern(self) -> Tuple[LayerSpec, ...]:
        """Derive the repeating super-block from the config knobs."""
        if self.arch_type == "ssm":
            return (LayerSpec(kind="mamba"),)
        if self.attn_every > 0:  # hybrid (jamba): 1 attn + (attn_every-1) mamba
            layers = []
            for i in range(self.attn_every):
                kind = "attn" if i == 0 else "mamba"
                moe = self.n_experts > 0 and (i % self.moe_every == self.moe_every - 1)
                layers.append(LayerSpec(kind=kind, moe=moe))
            return tuple(layers)
        if self.local_global_ratio > 0:  # gemma3: N local then 1 global
            local = [LayerSpec(kind="attn", window=self.sliding_window)
                     for _ in range(self.local_global_ratio)]
            return tuple(local + [LayerSpec(kind="attn", window=None)])
        # uniform dense / moe
        spec = LayerSpec(kind="attn", window=self.sliding_window,
                         moe=self.n_experts > 0)
        return (spec,)

    @property
    def n_blocks(self) -> int:
        bp = len(self.block_pattern())
        assert self.n_layers % bp == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"super-block size {bp}")
        return self.n_layers // bp

    def reduced(self, **overrides) -> "ModelConfig":
        """A CPU-smoke-test variant of the same family (2 blocks, tiny dims)."""
        bp = len(self.block_pattern())
        small = dict(
            n_layers=min(self.n_layers, 2 * bp),
            d_model=min(self.d_model, 128),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_d_ff=min(self.expert_d_ff, 128) if self.expert_d_ff else None,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            frontend_dims=tuple(min(d, 128) for d in self.frontend_dims),
            dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# unimodal *encoder* presets for the FL backbone adapter (fl/client.py)
# ---------------------------------------------------------------------------
#: backbone architectures the FL harness can train.  "lstm-cnn" is the
#: paper's faithful submodel pair (models/paper_models.py); the rest map
#: each modality's feature stack through a small encoder built from the
#: LM-scale blocks below (models/multimodal.py::encoder_apply) — the
#: scenario grid's architecture axis (data/scenarios.py).
ENCODER_ARCHS = ("transformer", "ssd")
FL_ARCHS = ("lstm-cnn",) + ENCODER_ARCHS

#: per-arch encoder stacks sized for federated clients (paper-model scale,
#: not LM scale): f32, 2 blocks, d_model 32.  ``ssm_chunk=8`` divides every
#: dataset's feature time axis (audio T=32, text T=24, image rows T=32 —
#: data/scenarios.py::DATASET_SHAPES), the ``ssd_chunked`` contract.
ENCODER_PRESETS = {
    "transformer": ModelConfig(
        name="fl-enc-transformer", arch_type="dense", n_layers=2,
        d_model=32, n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64,
        vocab_size=0, dtype="float32"),
    "ssd": ModelConfig(
        name="fl-enc-ssd", arch_type="ssm", n_layers=2,
        d_model=32, n_heads=4, n_kv_heads=4, head_dim=8, d_ff=0,
        vocab_size=0, ssm_state=16, ssm_head_dim=8, ssm_expand=2,
        ssm_conv=4, ssm_chunk=8, dtype="float32"),
}


def encoder_config(arch: str) -> ModelConfig:
    """The ``ModelConfig`` behind one FL encoder architecture."""
    try:
        return ENCODER_PRESETS[arch]
    except KeyError:
        raise ValueError(f"unknown encoder arch {arch!r}; "
                         f"choose from {ENCODER_ARCHS}") from None
