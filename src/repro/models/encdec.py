"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The modality frontend (mel-spectrogram + conv feature extractor) is a STUB per
the assignment carve-out: ``input_specs`` provides precomputed frame embeddings
``src_embeds`` of shape [B, S_src, d_model].  This module implements the
transformer backbone: a bidirectional encoder over frames + a causal decoder
with cross-attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L


def init_cross_attention(key, cfg: ModelConfig):
    # same parameter structure as self-attention (wq/wk/wv/wo)
    return L.init_attention(key, cfg)


def cross_attention_fwd(p, x, src, cfg: ModelConfig, *, chunk: int = 1024):
    """x: [B,Sq,D] queries; src: [B,Sk,D] encoder output."""
    B, Sq, _ = x.shape
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = L.dense(p["wq"], x).reshape(B, Sq, H, hd)
    k = L.dense(p["wk"], src).reshape(B, src.shape[1], K, hd)
    v = L.dense(p["wv"], src).reshape(B, src.shape[1], K, hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, p["k_norm"], cfg.norm_eps)
    o = L.chunked_attention(q, k, v, window=None, chunk=min(chunk, Sq),
                            causal=False)
    return L.dense(p["wo"], o.reshape(B, Sq, H * hd))


def init_encoder_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    dt = cfg.param_dtype
    return {
        "norm1": jnp.zeros((cfg.d_model,), dt),
        "attn": L.init_attention(k1, cfg),
        "norm2": jnp.zeros((cfg.d_model,), dt),
        "mlp": L.init_mlp(k2, cfg),
    }


def init_decoder_layer(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    dt = cfg.param_dtype
    return {
        "norm1": jnp.zeros((cfg.d_model,), dt),
        "self_attn": L.init_attention(k1, cfg),
        "norm_x": jnp.zeros((cfg.d_model,), dt),
        "cross_attn": init_cross_attention(k2, cfg),
        "norm2": jnp.zeros((cfg.d_model,), dt),
        "mlp": L.init_mlp(k3, cfg),
    }


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    dt = cfg.param_dtype
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "enc_blocks": jax.vmap(lambda k: init_encoder_layer(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: init_decoder_layer(k, cfg))(dec_keys),
        "embed": (jax.random.normal(ks[2], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "enc_norm": jnp.zeros((cfg.d_model,), dt),
        "dec_norm": jnp.zeros((cfg.d_model,), dt),
        "lm_head": (jax.random.normal(ks[3], (cfg.d_model, cfg.vocab_size),
                                      jnp.float32) * 0.02).astype(dt),
        "audio_head": {   # decision-fusion audio submodel head (cf. DESIGN §5)
            "w1": (jax.random.normal(ks[4], (cfg.d_model, cfg.d_model),
                                     jnp.float32) * 0.02).astype(dt),
            "w2": jnp.zeros((cfg.d_model, cfg.vocab_size), dt),
        },
    }


def encode(params, src_embeds, cfg: ModelConfig, *, attn_chunk: int = 1024):
    def blk(h, bp):
        a = L.rms_norm(h, bp["norm1"], cfg.norm_eps)
        B, S, _ = a.shape
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        q, k, v = L._project_qkv(bp["attn"], a, cfg, pos)
        a = L.chunked_attention(q, k, v, window=None,
                                chunk=min(attn_chunk, S), causal=False)
        a = L.dense(bp["attn"]["wo"], a.reshape(B, S, cfg.n_heads * cfg.hd))
        h = h + a
        m = L.rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], m)
        return h, None

    h, _ = jax.lax.scan(blk, src_embeds, params["enc_blocks"])
    return L.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def decode_fwd(params, tokens, enc_out, cfg: ModelConfig, *,
               attn_chunk: int = 1024):
    """tokens [B,S_tgt]; enc_out [B,S_src,D] -> logits [B,S_tgt,V]."""
    x = params["embed"][tokens]

    def blk(h, bp):
        a = L.rms_norm(h, bp["norm1"], cfg.norm_eps)
        h = h + L.attention_fwd(bp["self_attn"], a, cfg, window=None,
                                chunk=attn_chunk)
        c = L.rms_norm(h, bp["norm_x"], cfg.norm_eps)
        h = h + cross_attention_fwd(bp["cross_attn"], c, enc_out, cfg,
                                    chunk=attn_chunk)
        m = L.rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], m)
        return h, None

    h, _ = jax.lax.scan(blk, x, params["dec_blocks"])
    h = L.rms_norm(h, params["dec_norm"], cfg.norm_eps)
    return h @ params["lm_head"]


def audio_head_logits(params, enc_out):
    """Decision-fusion audio submodel: pooled encoder -> vocab logits [B,V]."""
    pooled = enc_out.mean(axis=1)
    h = jax.nn.gelu(pooled @ params["audio_head"]["w1"])
    return h @ params["audio_head"]["w2"]


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def init_dec_cache(cfg: ModelConfig, batch: int, seq: int, src_len: int,
                   dtype=None):
    dtype = dtype or cfg.param_dtype
    K, hd = cfg.n_kv_heads, cfg.hd
    nL = cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((nL, batch, seq, K, hd), dtype),
            "v": jnp.zeros((nL, batch, seq, K, hd), dtype),
        },
        # cross-attn K/V are computed once from the encoder output
        "cross_k": jnp.zeros((nL, batch, src_len, K, hd), dtype),
        "cross_v": jnp.zeros((nL, batch, src_len, K, hd), dtype),
    }


def cross_kv(params, enc_out, cfg: ModelConfig):
    """All decoder layers' cross-attention K/V in ONE stacked einsum over the
    layer axis — replaces the per-layer Python loop (n_layers ``tree.map``
    slices + small matmuls) with a single dense contraction.

    enc_out [B,S_src,D] -> (k, v) each [n_layers, B, S_src, K, hd], matching
    ``init_dec_cache``'s ``cross_k``/``cross_v`` layout.  Math parity with
    the loop: a plain dense per layer (plus qkv bias when the config carries
    one) — no qk_norm, exactly like ``decode_step``'s cached-K path.
    """
    B, Ssrc, _ = enc_out.shape
    nL, K, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    ca = params["dec_blocks"]["cross_attn"]

    def proj(wp):
        y = jnp.einsum("bsd,ldo->lbso", enc_out, wp["w"])
        if "b" in wp:
            y = y + wp["b"][:, None, None, :]
        return y.reshape(nL, B, Ssrc, K, hd)

    return proj(ca["wk"]), proj(ca["wv"])


def prefill_with_cache(params, tokens, enc_out, cache, cfg: ModelConfig, *,
                       attn_chunk: int = 1024):
    """Bulk decoder prefill: fill the self-attention cache in one chunked
    pass and return the last position's logits.

    tokens [B,S]; ``cache`` from ``init_dec_cache`` with ``cross_k``/
    ``cross_v`` already populated (``cross_kv``).  Returns
    (logits [B,V], cache ready for ``decode_step(..., index=S)``).
    """
    x = params["embed"][tokens]
    B, S, _ = x.shape

    def blk(h, inp):
        bp, bself = inp
        a = L.rms_norm(h, bp["norm1"], cfg.norm_eps)
        a, k, v = L.attention_prefill(bp["self_attn"], a, cfg, window=None,
                                      chunk=attn_chunk)
        newc = L.fill_attn_cache(bself, k, v, seq_len=S)
        h = h + a
        c = L.rms_norm(h, bp["norm_x"], cfg.norm_eps)
        h = h + cross_attention_fwd(bp["cross_attn"], c, enc_out, cfg,
                                    chunk=attn_chunk)
        m = L.rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], m)
        return h, newc

    h, new_self = jax.lax.scan(blk, x, (params["dec_blocks"], cache["self"]))
    h = L.rms_norm(h, params["dec_norm"], cfg.norm_eps)
    logits = h[:, -1, :] @ params["lm_head"]
    return logits, {**cache, "self": new_self}


def decode_step(params, cache, token, index, cfg: ModelConfig):
    """One decoder token against self-cache + precomputed cross K/V."""
    import math
    x = params["embed"][token]                                   # [B,1,D]
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    R = H // K
    B = x.shape[0]

    def blk(carry, inp):
        h = carry
        bp, bself, ck, cv = inp
        a = L.rms_norm(h, bp["norm1"], cfg.norm_eps)
        a, newc = L.attention_decode(bp["self_attn"], a, bself, index, cfg,
                                     window=None)
        h = h + a
        # cross attention against precomputed K/V (no mask)
        c = L.rms_norm(h, bp["norm_x"], cfg.norm_eps)
        q = L.dense(bp["cross_attn"]["wq"], c).reshape(B, 1, K, R, hd)
        qh = q.transpose(0, 2, 3, 1, 4)
        kh = ck.transpose(0, 2, 1, 3)
        vh = cv.transpose(0, 2, 1, 3)
        s = jnp.einsum("bgrqh,bgkh->bgrqk", qh, kh).astype(jnp.float32)
        s = s / math.sqrt(hd)
        w = jax.nn.softmax(s, axis=-1).astype(vh.dtype)
        o = jnp.einsum("bgrqk,bgkh->bgrqh", w, vh)
        o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, H * hd)
        h = h + L.dense(bp["cross_attn"]["wo"], o)
        m = L.rms_norm(h, bp["norm2"], cfg.norm_eps)
        h = h + L.mlp(bp["mlp"], m)
        return h, newc

    h, new_self = jax.lax.scan(
        blk, x, (params["dec_blocks"], cache["self"],
                 cache["cross_k"], cache["cross_v"]))
    h = L.rms_norm(h, params["dec_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    return logits, {**cache, "self": new_self}
