"""The paper's exact unimodal submodels (§VI "Models"), in JAX.

* Audio submodel (CREMA-D & IEMOCAP): unidirectional 2-layer LSTM
  (input 11, hidden=output=50), a 50-neuron hidden FC layer, and a C-neuron
  output layer; dropout 0.1 between LSTM layers during training.
* Text submodel (IEMOCAP): same with input 100, hidden 60, 10 outputs.
* Image submodel (CREMA-D): CNN with 3 conv layers of 16 5x5 kernels
  (3x5x5, 16x5x5, 16x5x5) each followed by 5x5 max-pooling with stride 3,
  then FC hidden layers of 64 and 32 neurons and a 6-neuron output layer.

Each submodel maps its modality's feature tensor to C-class logits — the
decision-level fusion and the unimodal losses are applied by
``repro.core.fusion`` exactly as in Eqs. (1)-(4).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# LSTM submodel
# ---------------------------------------------------------------------------
def _init_lstm_layer(key, d_in, d_h):
    k1, k2 = jax.random.split(key)
    s = 1.0 / math.sqrt(d_h)
    return {
        "wi": jax.random.uniform(k1, (d_in, 4 * d_h), minval=-s, maxval=s),
        "wh": jax.random.uniform(k2, (d_h, 4 * d_h), minval=-s, maxval=s),
        "b": jnp.zeros((4 * d_h,)),
    }


def init_lstm_model(key, d_in: int, d_h: int, n_classes: int):
    ks = jax.random.split(key, 4)
    return {
        "lstm0": _init_lstm_layer(ks[0], d_in, d_h),
        "lstm1": _init_lstm_layer(ks[1], d_h, d_h),
        "fc": {"w": jax.random.normal(ks[2], (d_h, d_h)) / math.sqrt(d_h),
               "b": jnp.zeros((d_h,))},
        "out": {"w": jax.random.normal(ks[3], (d_h, n_classes)) / math.sqrt(d_h),
                "b": jnp.zeros((n_classes,))},
    }


def _lstm_layer(p, x):
    """x: [B, T, d_in] -> outputs [B, T, d_h]."""
    return _lstm_scan(p["wi"], p["wh"], p["b"], x)


def _gate_acts(a):
    i, f, g, o = jnp.split(a, 4, axis=-1)
    return (jax.nn.sigmoid(i), jax.nn.sigmoid(f), jnp.tanh(g),
            jax.nn.sigmoid(o))


def _lstm_fwd_scan(wi, wh, b, x):
    """Time-major scan; the input projection x@wi is hoisted out of the scan
    as one large GEMM.  Returns (hs, pre-activations, cell states), all
    time-major [T, B, ...]."""
    B = x.shape[0]
    d_h = wh.shape[0]
    gx = (x @ wi + b).transpose(1, 0, 2)                  # [T, B, 4H]

    def cell(carry, gx_t):
        h, c = carry
        a = gx_t + h @ wh
        i, f, g, o = _gate_acts(a)
        c2 = f * c + i * g
        h2 = o * jnp.tanh(c2)
        return (h2, c2), (h2, a, c2)

    init = (jnp.zeros((B, d_h)), jnp.zeros((B, d_h)))
    _, (hs, a_s, cs) = jax.lax.scan(cell, init, gx)
    return hs, a_s, cs


@jax.custom_vjp
def _lstm_scan(wi, wh, b, x):
    hs, _, _ = _lstm_fwd_scan(wi, wh, b, x)
    return hs.transpose(1, 0, 2)


def _lstm_scan_fwd(wi, wh, b, x):
    hs, a_s, cs = _lstm_fwd_scan(wi, wh, b, x)
    return hs.transpose(1, 0, 2), (wi, wh, x, hs, a_s, cs)


def _lstm_scan_bwd(res, dout):
    """Hand-rolled VJP keeping the backward scan in *activation space*.

    Autodiff of the naive scan accumulates the [d_in, 4H] / [H, 4H] weight
    gradients inside the backward scan carry — under a per-client vmap that
    carry gains a K axis and the scan becomes memory-bound on [K, d_in, 4H]
    updates per step.  Here the scan only propagates (dh, dc) [B, H] and
    emits per-step gate gradients; every parameter gradient (and dx) is then
    one large post-scan GEMM, which is what makes the batched round engine's
    single-dispatch cohort update pay off (see fl/runtime.py).
    """
    wi, wh, x, hs, a_s, cs = res
    T, B, d_h = hs.shape
    dhs = dout.transpose(1, 0, 2)                         # [T, B, H]
    c_prev = jnp.concatenate([jnp.zeros((1, B, d_h)), cs[:-1]], axis=0)

    def cell(carry, inp):
        dh_next, dc_next = carry
        dh_t, a_t, c_t, cp_t = inp
        i, f, g, o = _gate_acts(a_t)
        tc = jnp.tanh(c_t)
        dh = dh_t + dh_next
        da_o = dh * tc * o * (1.0 - o)
        dc = dc_next + dh * o * (1.0 - tc * tc)
        da_i = dc * g * i * (1.0 - i)
        da_f = dc * cp_t * f * (1.0 - f)
        da_g = dc * i * (1.0 - g * g)
        da = jnp.concatenate([da_i, da_f, da_g, da_o], axis=-1)
        return (da @ wh.T, dc * f), da

    init = (jnp.zeros((B, d_h)), jnp.zeros((B, d_h)))
    _, das = jax.lax.scan(cell, init, (dhs, a_s, cs, c_prev), reverse=True)

    h_prev = jnp.concatenate([jnp.zeros((1, B, d_h)), hs[:-1]], axis=0)
    dwi = jnp.einsum("bti,tbg->ig", x, das)
    dwh = jnp.einsum("tbh,tbg->hg", h_prev, das)
    db = das.sum(axis=(0, 1))
    dx = (das @ wi.T).transpose(1, 0, 2)
    return dwi, dwh, db, dx


_lstm_scan.defvjp(_lstm_scan_fwd, _lstm_scan_bwd)


def lstm_apply(p, x, *, dropout_rng: Optional[jax.Array] = None,
               dropout: float = 0.1):
    """x: [B, T, d_in] -> logits [B, C]."""
    h = _lstm_layer(p["lstm0"], x)
    if dropout_rng is not None:
        # per-sample keys: sample i's mask depends only on (rng, i), never on
        # the batch size, so a client padded into a stacked [K, N, ...] batch
        # draws the same masks for its real samples as it does standalone —
        # the batched-vs-sequential equivalence invariant (fl/runtime.py)
        keys = jax.vmap(lambda i: jax.random.fold_in(dropout_rng, i))(
            jnp.arange(h.shape[0]))
        keep = jax.vmap(lambda k: jax.random.bernoulli(
            k, 1.0 - dropout, h.shape[1:]))(keys)
        h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    h = _lstm_layer(p["lstm1"], h)[:, -1, :]                  # last hidden
    h = jax.nn.relu(h @ p["fc"]["w"] + p["fc"]["b"])
    return h @ p["out"]["w"] + p["out"]["b"]


# ---------------------------------------------------------------------------
# CNN submodel
# ---------------------------------------------------------------------------
def init_cnn_model(key, n_classes: int = 6, in_ch: int = 3,
                   conv_scale: float = 0.35):
    """conv_scale < He: tames activation growth through the three
    maxpool(ReLU(conv)) stages so plain BGD at the shared η is stable
    (calibrated in EXPERIMENTS.md §Repro setup)."""
    ks = jax.random.split(key, 6)

    def conv(k, ci, co):
        return (jax.random.normal(k, (5, 5, ci, co))
                * math.sqrt(2.0 / (25 * ci)) * conv_scale)

    return {
        "c0": conv(ks[0], in_ch, 16),
        "c1": conv(ks[1], 16, 16),
        "c2": conv(ks[2], 16, 16),
        "fc0": {"w": jax.random.normal(ks[3], (64, 64)) / 8.0,
                "b": jnp.zeros((64,))},
        "fc1": {"w": jax.random.normal(ks[4], (64, 32)) / 8.0,
                "b": jnp.zeros((32,))},
        "out": {"w": jax.random.normal(ks[5], (32, n_classes)) / math.sqrt(32),
                "b": jnp.zeros((n_classes,))},
    }


def _maxpool1d(y, axis: int, window: int, stride: int):
    """SAME 1-D max-pool along ``axis`` as a max over strided slices."""
    H = y.shape[axis]
    out_h = -(-H // stride)
    ph = max((out_h - 1) * stride + window - H, 0)
    pad = [(0, 0)] * y.ndim
    pad[axis] = (ph // 2, ph - ph // 2)
    y = jnp.pad(y, pad, constant_values=-jnp.inf)
    out = None
    for i in range(window):
        idx = tuple(slice(None) if d != axis
                    else slice(i, i + (out_h - 1) * stride + 1, stride)
                    for d in range(y.ndim))
        out = y[idx] if out is None else jnp.maximum(out, y[idx])
    return out


def _maxpool(y, window: int = 5, stride: int = 3):
    """SAME 2-D max-pool, separated into two 1-D passes.

    Forward-identical to ``lax.reduce_window`` (max is exact and separable);
    the slice/select VJP avoids XLA's select-and-scatter and the separation
    does window+window instead of window² slice gradients.  (Tie-breaking
    differs — reduce_window credits the first maximum, jnp.maximum splits —
    a measure-zero event for real activations.)
    """
    return _maxpool1d(_maxpool1d(y, 1, window, stride), 2, window, stride)


def _conv_pool(x, w):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = jax.nn.relu(y)
    return _maxpool(y)


def cnn_apply(p, x, **_):
    """x: [B, 48, 48, 3] -> logits [B, C]."""
    y = _conv_pool(x, p["c0"])      # 16x16
    y = _conv_pool(y, p["c1"])      # 6x6
    y = _conv_pool(y, p["c2"])      # 2x2
    y = y.reshape(y.shape[0], -1)   # 64
    y = jax.nn.relu(y @ p["fc0"]["w"] + p["fc0"]["b"])
    y = jax.nn.relu(y @ p["fc1"]["w"] + p["fc1"]["b"])
    return y @ p["out"]["w"] + p["out"]["b"]


# ---------------------------------------------------------------------------
# dataset-level multimodal model builders
# ---------------------------------------------------------------------------
def init_crema_model(key):
    """CREMA-D: audio LSTM (11->50, 6 cls) + image CNN (48x48x3, 6 cls)."""
    k1, k2 = jax.random.split(key)
    return {"audio": init_lstm_model(k1, 11, 50, 6),
            "image": init_cnn_model(k2, 6)}


def init_iemocap_model(key):
    """IEMOCAP: audio LSTM (11->50, 10 cls) + text LSTM (100->60, 10 cls)."""
    k1, k2 = jax.random.split(key)
    return {"audio": init_lstm_model(k1, 11, 50, 10),
            "text": init_lstm_model(k2, 100, 60, 10)}


MODAL_APPLY = {"audio": lstm_apply, "text": lstm_apply, "image": cnn_apply}

#: stable per-modality dropout-stream constants: index in sorted *global*
#: modality order, NOT order within the call's ``inputs`` — a client
#: training a modality subset folds the same constant as the full-stack
#: batched path, and the constant is identical across processes.  (Earlier
#: revisions folded in Python's ``hash(m)``, which PYTHONHASHSEED
#: randomises per process, so dropout masks differed across runs; any
#: seed-sensitive trajectory from before that fix is not comparable
#: bit-for-bit.)
MODALITY_INDEX = {m: i for i, m in enumerate(sorted(MODAL_APPLY))}


def modal_logits(params, inputs: dict, *, dropout_rng=None,
                 dropout: float = 0.1):
    """Per-modality logits for whichever modalities are present in `inputs`."""
    out = {}
    for m in sorted(inputs):
        rng = None
        if dropout_rng is not None:
            rng = jax.random.fold_in(dropout_rng, MODALITY_INDEX[m])
        out[m] = MODAL_APPLY[m](params[m], inputs[m], dropout_rng=rng,
                                dropout=dropout)
    return out


def param_bits(params, bits_per_param: int = 32) -> int:
    """Upload size in bits (cf. paper's l_m table)."""
    return sum(x.size for x in jax.tree.leaves(params)) * bits_per_param
