"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in JAX.

Train/prefill uses the chunked SSD algorithm: intra-chunk attention-like matmul
(MXU friendly) + an inter-chunk ``lax.scan`` over the recurrent state.  The
intra-chunk contraction is the compute hot spot and has a Pallas TPU kernel in
``repro.kernels.ssd_scan`` (validated vs. ``ref.py`` in interpret mode); the
pure-jnp path here is the dry-run/XLA path.

Sharding note (DESIGN.md §6): the canonical fused ``in_proj`` of the reference
implementation concatenates z|x|B|C|dt in one output dim — slicing that dim is
hostile to tensor-parallel sharding (misaligned shard boundaries force
reshards).  We keep z/x/dt projections as separate arrays sharded over the
``model`` axis (heads/d_inner are model-parallel) and replicate the tiny B/C
projections (N=16..128).  The math is identical.

Decode keeps a constant-size cache: depthwise-conv tails + SSM state
[B, nh, N, hp] — this is what makes long_500k decoding O(1) per token.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from .config import ModelConfig


def init_mamba(key, cfg: ModelConfig):
    D, di, N, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_n_heads
    dt = cfg.param_dtype
    ks = jax.random.split(key, 7)

    def proj(k, dout):
        return (jax.random.normal(k, (D, dout), jnp.float32)
                / math.sqrt(D)).astype(dt)

    return {
        "wz": proj(ks[0], di),
        "wx": proj(ks[1], di),
        "wB": proj(ks[2], N),
        "wC": proj(ks[3], N),
        "wdt": proj(ks[4], nh),
        "conv_x": (jax.random.normal(ks[6], (cfg.ssm_conv, di), jnp.float32)
                   * 0.1).astype(dt),
        "conv_B": jnp.zeros((cfg.ssm_conv, N), dt) .at[-1].set(1.0),
        "conv_C": jnp.zeros((cfg.ssm_conv, N), dt) .at[-1].set(1.0),
        "conv_bx": jnp.zeros((di,), dt),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm": jnp.zeros((di,), dt),
        "out_proj": (jax.random.normal(ks[5], (di, D), jnp.float32)
                     / math.sqrt(di)).astype(dt),
    }


def _causal_conv(x, w, b=None):
    """Depthwise causal conv, kernel K. x: [B,S,C], w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    if b is not None:
        out = out + b
    return jax.nn.silu(out)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, return_state: bool = False):
    """Chunked SSD.

    x:  [B, S, nh, hp]   (conv'd + silu'd input)
    dt: [B, S, nh]       (post-softplus step sizes, fp32)
    A:  [nh]             (negative, fp32)
    Bm: [B, S, N], Cm: [B, S, N]
    Returns y: [B, S, nh, hp] (x.dtype); with ``return_state`` also the
    final recurrent state h_S [B, nh, N, hp] fp32 — the inter-chunk scan's
    final carry, identical to the state the sequential ``mamba_decode``
    recurrence reaches after S tokens (prefill cache export).
    """
    Bsz, S, nh, hp = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xd = x.astype(jnp.float32) * dt[..., None]                    # dt-weighted
    dtA = dt * A[None, None, :]                                   # [B,S,nh]

    xc = xd.reshape(Bsz, nc, Q, nh, hp)
    dAc = dtA.reshape(Bsz, nc, Q, nh)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)

    # --- intra-chunk (diagonal blocks): attention-like, MXU-friendly ---
    cum = jnp.cumsum(dAc, axis=2)                                 # [B,nc,Q,nh]
    # decay matrix L[t,s] = exp(cum_t - cum_s), lower-triangular.
    # Mask the EXPONENT (not the exp) — upper-triangle diffs are large
    # positive, exp overflows to inf, and 0*inf poisons the backward pass.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # [B,nc,Q,Q,nh]
    tri = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    Lmat = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("bctn,bcsn->bcts", Cc, Bc)                # [B,nc,Q,Q]
    y_diag = jnp.einsum("bctsh,bcts,bcshp->bcthp", Lmat, scores, xc)

    # --- chunk summary states: S_c = Σ_s exp(cum_last − cum_s) B_s x_s^T ---
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)               # [B,nc,Q,nh]
    states = jnp.einsum("bcsn,bcsh,bcshp->bchnp", Bc, decay_to_end, xc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # [B,nc,nh]

    # --- inter-chunk recurrence (lax.scan keeps memory flat) ---
    def body(h, inp):
        st, dec = inp                                             # [B,nh,N,hp], [B,nh]
        h_before = h
        h = h * dec[..., None, None] + st
        return h, h_before

    h0 = jnp.zeros((Bsz, nh, N, hp), jnp.float32)
    h_last, h_prev = jax.lax.scan(
        body, h0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)                      # [B,nc,nh,N,hp]

    # --- inter-chunk contribution: y_off[t] = C_t · (exp(cum_t) * h_prev) ---
    in_decay = jnp.exp(cum)                                       # [B,nc,Q,nh]
    y_off = jnp.einsum("bctn,bcth,bchnp->bcthp", Cc, in_decay, h_prev)

    y = (y_diag + y_off).reshape(Bsz, S, nh, hp)
    if return_state:
        return y.astype(x.dtype), h_last
    return y.astype(x.dtype)


# ----------------------------------------------------------------------------
# Pallas-backed SSD with an XLA-recompute backward.  The intra-chunk kernel
# (repro.kernels.ssd_scan, interpret mode off-TPU) has no backward kernel, so
# ``ssd_pallas`` pairs the kernel forward with a custom VJP that replays
# ``ssd_chunked`` under ``jax.vjp`` — gradients are exactly the XLA path's
# (the forwards match, tests/test_kernels.py), which is what lets the FL
# backbone adapter train through the kernel (fl/client.py, impl="pallas").
# ----------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd_pallas(x, dt, A, Bm, Cm, chunk: int):
    """Same contract as ``ssd_chunked`` (no ``return_state``)."""
    from ..kernels.ssd_scan.ops import ssd_forward
    return ssd_forward(x, dt, A, Bm, Cm, chunk)


def _ssd_pallas_fwd(x, dt, A, Bm, Cm, chunk):
    return ssd_pallas(x, dt, A, Bm, Cm, chunk), (x, dt, A, Bm, Cm)


def _ssd_pallas_bwd(chunk, res, g):
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(
        lambda *a: ssd_chunked(*a, chunk), x, dt, A, Bm, Cm)
    return vjp(g)


ssd_pallas.defvjp(_ssd_pallas_fwd, _ssd_pallas_bwd)


def mamba_fwd(p, u, cfg: ModelConfig, *, impl: str = "xla"):
    """u: [B, S, D] -> [B, S, D].  ``impl="pallas"`` routes the chunked-SSD
    contraction through the Pallas kernel (``ssd_pallas`` above)."""
    B, S, D = u.shape
    nh, hp, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = u @ p["wz"]
    x = _causal_conv(u @ p["wx"], p["conv_x"], p["conv_bx"])
    Bm = _causal_conv(u @ p["wB"], p["conv_B"])
    Cm = _causal_conv(u @ p["wC"], p["conv_C"])
    dt = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, S, nh, hp)
    ssd = ssd_pallas if impl == "pallas" else ssd_chunked
    y = ssd(xh, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, cfg.d_inner)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


# ----------------------------------------------------------------------------
# decode
# ----------------------------------------------------------------------------
def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    K = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype=dtype),
        "conv_B": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype=dtype),
        "conv_C": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype=dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_state,
                          cfg.ssm_head_dim), dtype=jnp.float32),
    }


def _conv_step(tail, new, w, b=None):
    """tail: [B,K-1,C]; new: [B,C] -> (out [B,C], new_tail)."""
    window = jnp.concatenate([tail, new[:, None, :].astype(tail.dtype)], axis=1)
    out = jnp.einsum("bkc,kc->bc", window, w)
    if b is not None:
        out = out + b
    return jax.nn.silu(out), window[:, 1:, :]


def mamba_decode(p, u, cache: dict, cfg: ModelConfig):
    """u: [B, 1, D] -> (y [B,1,D], new_cache)."""
    B = u.shape[0]
    nh, hp, N = cfg.ssm_n_heads, cfg.ssm_head_dim, cfg.ssm_state
    u0 = u[:, 0]
    z = u0 @ p["wz"]
    x, tx = _conv_step(cache["conv_x"], u0 @ p["wx"], p["conv_x"], p["conv_bx"])
    Bm, tB = _conv_step(cache["conv_B"], u0 @ p["wB"], p["conv_B"])
    Cm, tC = _conv_step(cache["conv_C"], u0 @ p["wC"], p["conv_C"])
    dt = jax.nn.softplus((u0 @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A[None, :])                                 # [B,nh]
    xh = x.reshape(B, nh, hp).astype(jnp.float32)
    h = cache["ssm"] * dec[..., None, None] + \
        jnp.einsum("bn,bhp->bhnp", Bm.astype(jnp.float32), xh * dt[..., None])
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, cfg.d_inner).astype(u.dtype)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None, :]
    return out, {"conv_x": tx, "conv_B": tB, "conv_C": tC, "ssm": h}


def _conv_tail(raw, K: int):
    """Last K-1 raw (pre-activation) projections [B,S,C] -> [B,K-1,C],
    zero-padded on the left when S < K-1 — matching the implicit zero
    history of ``_causal_conv`` and the zeros of ``init_mamba_cache``."""
    B, S, C = raw.shape
    t = raw[:, max(S - (K - 1), 0):, :]
    pad = (K - 1) - t.shape[1]
    if pad:
        t = jnp.pad(t, ((0, 0), (pad, 0), (0, 0)))
    return t


def mamba_prefill(p, u, cfg: ModelConfig):
    """Bulk prefill: the chunked-SSD forward plus a decode-cache export.

    u: [B, S, D] -> (y [B,S,D], cache) where ``cache`` is exactly the state
    S sequential ``mamba_decode`` steps would have left behind: conv tails
    hold the last ``ssm_conv - 1`` raw projections and ``ssm`` is the
    chunked scan's final fp32 recurrent state (cf. ``ssd_chunked``'s
    ``return_state`` — the chunked/sequential duality).
    """
    B, S, D = u.shape
    nh, hp = cfg.ssm_n_heads, cfg.ssm_head_dim
    z = u @ p["wz"]
    xr, Br, Cr = u @ p["wx"], u @ p["wB"], u @ p["wC"]
    x = _causal_conv(xr, p["conv_x"], p["conv_bx"])
    Bm = _causal_conv(Br, p["conv_B"])
    Cm = _causal_conv(Cr, p["conv_C"])
    dt = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(B, S, nh, hp)
    Q = min(cfg.ssm_chunk, S)
    while S % Q:                   # self-adjust to a divisor of S
        Q //= 2
    y, h = ssd_chunked(xh, dt, A, Bm, Cm, Q, return_state=True)
    y = y + xh * p["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(B, S, cfg.d_inner)
    from .layers import rms_norm
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    K = cfg.ssm_conv
    cache = {"conv_x": _conv_tail(xr, K), "conv_B": _conv_tail(Br, K),
             "conv_C": _conv_tail(Cr, K), "ssm": h}
    return y @ p["out_proj"], cache
