"""Decision-level-fusion multimodal wrappers for the LM-scale architectures.

The paper's architecture (Fig. 2) is M unimodal submodels whose *logits* are
averaged (parameter-free fusion), with a per-modality unimodal CE added to the
objective (Eqs. 1-4).  We realise exactly that structure at LM scale:

* llava-next-34b (vlm): text submodel = the 60L backbone on text tokens;
  vision submodel = a light head on pooled anyres patch embeddings (frontend
  STUB per the carve-out) producing vocab logits broadcast over positions.
  Fused logits = mean of available modalities' logits, as in Eq. (1).
* whisper-base (audio): the enc-dec backbone gives (audio-conditioned) decoder
  logits; the audio submodel head pools the encoder.  See ``encdec.py``.

The actual fusion / unimodal-loss math lives in ``repro.core.fusion`` and is
shared with the faithful paper models — this module only produces the
per-modality logits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import math

from .config import ModelConfig
from . import transformer as T


# ---------------------------------------------------------------------------
# unimodal *classification* encoders for the FL harness (fl/client.py)
# ---------------------------------------------------------------------------
def init_encoder(key, d_in: int, n_classes: int, cfg: ModelConfig):
    """A small sequence encoder: linear proj -> ``cfg`` block stack -> head.

    Maps one modality's feature stack [B, T, *feat] to C-class decision
    logits, playing the same role as the paper's LSTM/CNN submodels but with
    the LM-scale transformer / mamba2 blocks (``ENCODER_PRESETS`` in
    config.py).  Params carry ``"blocks"`` / ``"final_norm"`` exactly as
    ``transformer.init_params`` does, so ``T.backbone`` runs the stack
    unchanged (incl. remat and the Pallas ``impl`` routing).
    """
    pattern = cfg.block_pattern()
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype

    def one_block(bk):
        bks = jax.random.split(bk, len(pattern))
        return {f"l{i}": T.init_layer(bks[i], cfg, spec)
                for i, spec in enumerate(pattern)}

    blocks = jax.vmap(one_block)(jax.random.split(ks[0], cfg.n_blocks))
    return {
        "proj": {"w": (jax.random.normal(ks[1], (d_in, cfg.d_model),
                                         jnp.float32)
                       / math.sqrt(d_in)).astype(dt),
                 "b": jnp.zeros((cfg.d_model,), dt)},
        "blocks": blocks,
        "final_norm": jnp.zeros((cfg.d_model,), dt),
        "head": {"w": (jax.random.normal(ks[2], (cfg.d_model, n_classes),
                                         jnp.float32)
                       / math.sqrt(cfg.d_model)).astype(dt),
                 "b": jnp.zeros((n_classes,), dt)},
    }


def encoder_apply(p, x, cfg: ModelConfig, *, dropout_rng=None,
                  dropout: float = 0.1, remat: bool = False,
                  impl: str = "xla"):
    """x: [B, T, *feat] -> logits [B, C].

    Trailing feature dims are flattened per time step (an image stack
    [B, 32, 32, 3] becomes a 32-step sequence of 96-dim rows).  Dropout is
    applied to the pooled last-position representation with *per-sample*
    keys — sample i's mask depends only on (rng, i), never the batch size,
    preserving the batched-vs-sequential equivalence invariant the cohort
    vmap relies on (fl/runtime.py; same discipline as ``lstm_apply``).
    """
    B, S = x.shape[0], x.shape[1]
    h = x.reshape(B, S, -1) @ p["proj"]["w"] + p["proj"]["b"]
    h, _ = T.backbone(p, h, cfg, attn_chunk=S, remat=remat, impl=impl)
    h = h[:, -1, :]                                          # [B, D]
    if dropout_rng is not None:
        keys = jax.vmap(lambda i: jax.random.fold_in(dropout_rng, i))(
            jnp.arange(B))
        keep = jax.vmap(lambda k: jax.random.bernoulli(
            k, 1.0 - dropout, h.shape[1:]))(keys)
        h = jnp.where(keep, h / (1.0 - dropout), 0.0)
    return h @ p["head"]["w"] + p["head"]["b"]


def init_vlm_params(key, cfg: ModelConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    p = T.init_params(k1, cfg)
    d_patch = cfg.frontend_dims[0] if cfg.frontend_dims else cfg.d_model
    dt = cfg.param_dtype
    p["vision"] = {
        # projector: patch embedding -> d_model (anyres tiles pre-flattened)
        "proj": (jax.random.normal(k2, (d_patch, cfg.d_model), jnp.float32)
                 * 0.02).astype(dt),
        # vision decision head: pooled patches -> vocab logits
        "w1": (jax.random.normal(k3, (cfg.d_model, cfg.d_model), jnp.float32)
               * 0.02).astype(dt),
        "w2": jnp.zeros((cfg.d_model, cfg.vocab_size), dt),
    }
    return p


def vlm_modal_logits(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
                     attn_chunk: int = 1024, **bk):
    """batch: {"tokens": [B,S], "patches": [B,P,d_patch]}.

    Returns ({"text": [B,S,V], "vision": [B,1,V]}, moe_aux).
    The vision logits broadcast over sequence positions during fusion.
    """
    tokens = batch["tokens"]
    patches = batch["patches"]
    text_logits, aux = T.forward(params, tokens, cfg, n_groups=n_groups,
                                 attn_chunk=attn_chunk, **bk)
    pv = patches @ params["vision"]["proj"]                 # [B,P,D]
    pooled = pv.mean(axis=1)                                # [B,D]
    h = jax.nn.gelu(pooled @ params["vision"]["w1"])
    vision_logits = (h @ params["vision"]["w2"])[:, None, :]  # [B,1,V]
    return {"text": text_logits, "vision": vision_logits}, aux


def vlm_fused_forward(params, batch, cfg: ModelConfig, **kw):
    """Fused logits per Eq. (1): average of available modal logits."""
    modal, aux = vlm_modal_logits(params, batch, cfg, **kw)
    fused = 0.5 * (modal["text"] + modal["vision"])         # broadcast over S
    return fused, modal, aux


def vlm_loss_chunked(params, batch, cfg: ModelConfig, chunk: int, *,
                     n_groups: int = 1, attn_chunk: int = 1024, **bk):
    """Streaming decision-fusion loss: unembed + fused CE + both unimodal CEs
    computed per sequence chunk — the [B,S,V] text logits and the fused
    logits are never materialised (XLA analogue of the fusion_loss Pallas
    kernel; §Perf hillclimb for the vlm train shape).

    Returns (total_loss, moe_aux)."""
    tokens, labels, patches = batch["tokens"], batch["labels"], batch["patches"]
    x = T.embed_tokens(params, tokens, cfg)
    h, aux = T.backbone(params, x, cfg, n_groups=n_groups,
                        attn_chunk=attn_chunk, **bk)
    pv = patches @ params["vision"]["proj"]
    pooled = pv.mean(axis=1)
    hv = jax.nn.gelu(pooled @ params["vision"]["w1"])
    vision_logits = (hv @ params["vision"]["w2"]).astype(jnp.float32)  # [B,V]

    B, S, D = h.shape
    chunk = min(chunk, S)
    while S % chunk != 0:
        chunk //= 2
    nc = S // chunk
    hc = h.reshape(B, nc, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nc, chunk).transpose(1, 0, 2)

    # vision unimodal CE is position-independent: one term, exact
    v_lse = jax.nn.logsumexp(vision_logits, axis=-1)                 # [B]

    def body(carry, xs):
        t_tot, f_tot = carry
        hh, ll = xs
        text = T.unembed(params, hh, cfg).astype(jnp.float32)        # [B,c,V]
        t_lse = jax.nn.logsumexp(text, axis=-1)
        gold_t = jnp.take_along_axis(text, ll[..., None], -1)[..., 0]
        fused = 0.5 * (text + vision_logits[:, None, :])
        f_lse = jax.nn.logsumexp(fused, axis=-1)
        gold_f = jnp.take_along_axis(fused, ll[..., None], -1)[..., 0]
        return (t_tot + (t_lse - gold_t).sum(),
                f_tot + (f_lse - gold_f).sum()), None

    (t_tot, f_tot), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc))
    n = B * S
    # vision unimodal CE broadcast over positions: lse is per-B constant,
    # the gold logit varies with the per-position label
    G_vision = (v_lse[:, None]
                - jnp.take_along_axis(vision_logits, labels, axis=-1)).mean()
    return t_tot / n + f_tot / n + G_vision, aux
