"""Decision-level fusion + unimodal loss — Eqs. (1)-(4) of the paper.

* The multimodal decision is the *average of unimodal logits over the client's
  available modalities* (missing modalities contribute 0 and are excluded from
  the mean) — Eq. (1) / Fig. 2.
* The local objective adds, for each available modality, a weighted unimodal
  cross-entropy v_m * CE(logits_m, y) — Eqs. (2)-(3).
* Total local loss H_k = F_k + G_k — Eq. (4).  The unimodal terms reuse the
  already-computed unimodal logits, so the extra cost is only the CE itself —
  the "no additional computational overhead" property the paper emphasises.

These functions are shared between the faithful paper models (logits [B, C])
and the LM-scale architectures (logits [B, S, V]); everything broadcasts.
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 sample_mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy, fp32. logits [..., C]; labels [...] int.

    ``sample_mask`` (0/1, broadcastable to ``labels``) restricts the mean to
    real samples — padded rows of a stacked client batch contribute nothing,
    so the masked mean over n real samples equals the plain mean over an
    unpadded [n] batch (the batched-round-engine equivalence invariant).
    """
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[..., None], axis=-1)[..., 0]
    ce = lse - gold
    if sample_mask is None:
        return ce.mean()
    w = jnp.broadcast_to(jnp.asarray(sample_mask, jnp.float32), ce.shape)
    return (ce * w).sum() / jnp.maximum(w.sum(), 1e-9)


def fuse_logits(modal_logits: Mapping[str, jax.Array],
                avail: Optional[Mapping[str, jax.Array]] = None) -> jax.Array:
    """Eq. (1) fusion: mean of available modalities' logits.

    ``avail[m]`` is an optional 0/1 scalar (or [B]-vector) availability mask;
    by default every modality present in the dict is available.  Logit tensors
    may broadcast against each other (e.g. vision [B,1,V] + text [B,S,V]).
    """
    num, den = None, None
    for m, lg in modal_logits.items():
        a = jnp.asarray(1.0 if avail is None else avail[m], jnp.float32)
        while a.ndim < lg.ndim:
            a = a[..., None]
        term = lg.astype(jnp.float32) * a
        num = term if num is None else num + term
        den = a if den is None else den + a
    return num / jnp.maximum(den, 1e-9)


def multimodal_loss(modal_logits: Mapping[str, jax.Array],
                    labels: jax.Array,
                    v_weights: Optional[Mapping[str, float]] = None,
                    avail: Optional[Mapping[str, jax.Array]] = None,
                    sample_mask: Optional[jax.Array] = None):
    """H_k = F_k + G_k (Eqs. 1-4).

    ``avail[m]`` zeroes out a modality the client lacks (or dropped), and
    ``sample_mask`` zeroes out padded samples — together they let one jitted
    computation over a dense [K, N, ...] stack reproduce the per-client
    ragged losses exactly (see fl/runtime.py).

    Returns (total, metrics) where metrics holds F, each unimodal G_m, and the
    fused logits for accuracy computation.
    """
    fused = fuse_logits(modal_logits, avail)
    F = softmax_xent(fused, labels, sample_mask)
    G = jnp.zeros((), jnp.float32)
    metrics: Dict[str, jax.Array] = {"F": F}
    for m, lg in modal_logits.items():
        v = 1.0 if v_weights is None else float(v_weights.get(m, 1.0))
        a = jnp.asarray(1.0 if avail is None else avail[m], jnp.float32)
        if lg.ndim == labels.ndim + 1 and lg.shape[:-1] == labels.shape:
            g = softmax_xent(lg, labels, sample_mask)
        else:
            # broadcast logits (e.g. vision head [B,1,V] vs labels [B,S])
            g = softmax_xent(jnp.broadcast_to(
                lg, labels.shape + lg.shape[-1:]), labels, sample_mask)
        g = v * jnp.mean(a) * g
        metrics[f"G_{m}"] = g
        G = G + g
    metrics["G"] = G
    metrics["fused_logits"] = fused
    return F + G, metrics


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (jnp.argmax(logits, axis=-1) == labels).mean()
