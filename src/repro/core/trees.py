"""Shared pytree reductions — squared norms, dots, distances.

One implementation for every consumer of ‖·‖-type statistics over parameter
or gradient pytrees: the Theorem-1 ζ/δ trackers (``core.convergence``), the
Selection scheduler's ‖θ_k − θ⁰‖ bookkeeping (``fl/client.py`` cohort step)
and the host round loops.  All reductions are leaf-ordered sums of
``jnp.vdot`` contractions, so host and traced callers see bit-identical
results for the same pytree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_sq_norm(tree):
    """Σ_leaves ‖x‖² (a 0-d array under trace, a scalar array on host)."""
    return sum(jnp.vdot(x, x).real for x in jax.tree.leaves(tree))


def tree_norm(tree):
    return jnp.sqrt(tree_sq_norm(tree))


def tree_dot(a, b):
    """Σ_leaves ⟨x, y⟩ over two pytrees of identical structure."""
    return sum(jnp.vdot(x, y).real
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def tree_sq_dist(a, b):
    """Σ_leaves ‖x − y‖² — squared distance between two pytrees."""
    return sum(jnp.vdot(x - y, x - y).real
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))
