"""Convergence-bound bookkeeping — Theorem 1 / Theorem 2 of the paper.

Theorem 1 bounds H(θ^t) − H(ψ^t) ≤ ηρ √(A₁ + A₂) with

    A₁ = Σ_{m ∉ M^t} (ζ_m^{t-1})²                       (unscheduled modality)
    A₂ = Σ_{m ∈ M^t} 2 (1 − Σ_{k∈K_m} a_k w̄_{k,m}) ·
         Σ_{k∈K_m} (w^t_{k,m} + w̄_{k,m} − 2 a_k w̄_{k,m}) (δ_{k,m}^{t-1})²

The server cannot see round-t gradients before scheduling, so — as the paper
does implicitly ("scheduling results of modalities and clients" with t−1
superscripts) — ζ and δ are tracked from the gradients uploaded in previous
rounds:

    ζ_m   ← ‖∇H(θ_{g,m})‖        (norm of the aggregated unimodal subgradient)
    δ_k,m ← ‖∇H_k(θ_{g,m}) − ∇H(θ_{g,m})‖   (client-to-global divergence)

Stale entries decay toward the modality mean so never-scheduled clients stay
schedulable.  ``bound_term(a)`` evaluates ηρ√(A₁+A₂) for a candidate
participation vector — this is exactly the V-weighted term of the JCSBA
objective J₁ (P3, Eq. 32).
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .trees import tree_norm, tree_sq_norm


def _tree_norm(tree) -> float:
    return float(tree_norm(tree))


def _tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y, a, b)


class BoundState:
    """Tracks ζ_m and δ_{k,m} and evaluates the Theorem-1 bound."""

    def __init__(self, n_clients: int, all_modalities: Sequence[str],
                 client_modalities: Sequence[Sequence[str]],
                 unified_w: Mapping[str, np.ndarray],
                 data_sizes: Sequence[int],
                 eta: float = 0.1, rho: float = 1.0,
                 init_zeta: float = 1.0, init_delta: float = 0.3,
                 staleness: float = 0.9):
        # init_delta < init_zeta: early in training local gradients are far
        # better aligned with the global gradient than their norms are to
        # zero, so the cold-start bound must prefer scheduling over idling
        # (otherwise round 0 schedules nobody and the trackers never update).
        self.K = n_clients
        self.mods = list(all_modalities)
        self.client_mods = [set(m) for m in client_modalities]
        self.w_bar = {m: np.asarray(unified_w[m], np.float64) for m in self.mods}
        self.D = np.asarray(data_sizes, np.float64)
        self.eta, self.rho = eta, rho
        self.zeta = {m: init_zeta for m in self.mods}
        self.delta = {m: np.full(n_clients, init_delta) for m in self.mods}
        self.staleness = staleness

    # ------------------------------------------------------------------
    def update(self, grads_by_client: List[Optional[Mapping[str, object]]],
               agg_grads: Mapping[str, object]) -> None:
        """Refresh ζ/δ from the gradients uploaded this round."""
        for m in self.mods:
            if m not in agg_grads:
                continue
            self.zeta[m] = _tree_norm(agg_grads[m])
            seen = []
            for k, g in enumerate(grads_by_client):
                if g is None or m not in g:
                    continue
                self.delta[m][k] = _tree_norm(_tree_sub(g[m], agg_grads[m]))
                seen.append(k)
            if seen:
                mean_d = float(np.mean([self.delta[m][k] for k in seen]))
                for k in range(self.K):
                    if k not in seen and m in self.client_mods[k]:
                        # decay stale entries toward the fresh mean
                        self.delta[m][k] = (self.staleness * self.delta[m][k]
                                            + (1 - self.staleness) * mean_d)

    # ------------------------------------------------------------------
    def update_stacked(self, stacked_grads: Mapping[str, object],
                       upload_mask: Mapping[str, np.ndarray],
                       agg_grads: Mapping[str, object]) -> None:
        """Vectorized twin of ``update`` for the batched round engine:
        ``stacked_grads[m]`` carries a leading client axis [K, ...] and
        ``upload_mask[m]`` (bool [K]) marks which rows are real uploads —
        masked-out rows hold exact zeros and are ignored.  Produces the same
        ζ/δ values as the sequential path."""
        for m in self.mods:
            if m not in agg_grads:
                continue
            mask = np.asarray(upload_mask[m], bool)
            seen = np.flatnonzero(mask)
            if not seen.size:
                continue
            self.zeta[m] = _tree_norm(agg_grads[m])
            # per-client norms on device: only the [K] result crosses the
            # host boundary, not the K-times-model-size gradient stack
            sq = sum(jnp.square(gs - ga[None]).reshape(self.K, -1).sum(axis=1)
                     for gs, ga in zip(jax.tree.leaves(stacked_grads[m]),
                                       jax.tree.leaves(agg_grads[m])))
            norms = np.asarray(jnp.sqrt(sq))
            self.delta[m][seen] = norms[seen]
            mean_d = float(norms[seen].mean())
            stale = np.array([m in cm for cm in self.client_mods]) & ~mask
            self.delta[m][stale] = (self.staleness * self.delta[m][stale]
                                    + (1 - self.staleness) * mean_d)

    # ------------------------------------------------------------------
    def a1_a2(self, a: np.ndarray) -> tuple:
        """A₁, A₂ of Theorem 1 for participation vector a ∈ {0,1}^K."""
        a = np.asarray(a, np.float64)
        A1 = 0.0
        A2 = 0.0
        for m in self.mods:
            has = np.array([m in cm for cm in self.client_mods], bool)
            part = has & (a > 0.5)
            if not part.any():                      # m ∉ M^t
                A1 += self.zeta[m] ** 2
                continue
            wbar = self.w_bar[m]
            # participated weights w^t_{k,m}
            wt = np.where(part, self.D, 0.0)
            wt = wt / wt.sum()
            cover = float((a * wbar).sum())         # Σ a_k w̄_{k,m}
            coeff = wt + wbar - 2.0 * a * wbar
            A2 += 2.0 * (1.0 - cover) * float(
                (coeff * np.square(self.delta[m])).sum())
        return A1, max(A2, 0.0)

    def bound_term(self, a: np.ndarray) -> float:
        """ηρ√(A₁+A₂) — the scheduling-dependent part of Theorem 2."""
        A1, A2 = self.a1_a2(a)
        return self.eta * self.rho * float(np.sqrt(A1 + A2))

    def descent_bound(self, grad_sq_sum: float, gamma: float,
                      a: np.ndarray) -> float:
        """Full Theorem-2 RHS: −(2η−γη²)/2 Σ‖∇H_m‖² + ηρ√(A₁+A₂)."""
        return (-(2 * self.eta - gamma * self.eta ** 2) / 2.0 * grad_sq_sum
                + self.bound_term(a))

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict[str, np.ndarray]:
        """Dense-array view of the tracker state for the batched solver.

        Everything ``a1_a2`` / ``objective`` read from Python dicts is packed
        into [M]/[M, K] arrays so the Theorem-1 term can be evaluated for a
        whole antibody population inside one jitted program
        (``objective_batched``).  A fresh snapshot must be taken every round —
        ζ/δ move whenever ``update``/``update_stacked`` run."""
        M, K = len(self.mods), self.K
        has = np.zeros((M, K), bool)
        for i, m in enumerate(self.mods):
            for k in range(K):
                has[i, k] = m in self.client_mods[k]
        return {
            "zeta2": np.array([self.zeta[m] ** 2 for m in self.mods]),
            "delta2": np.stack([np.square(self.delta[m])
                                for m in self.mods]) if M else
                      np.zeros((0, K)),
            "wbar": np.stack([self.w_bar[m] for m in self.mods]) if M else
                    np.zeros((0, K)),
            "has": has,
            "D": self.D,
        }

    def objective(self, a: np.ndarray, gamma: float = 1.0) -> float:
        """Scheduling objective = Theorem-2 RHS restricted to a-dependent
        terms, INCLUDING the descent credit of covered modalities.

        The paper's P3 keeps only ηρ√(A₁+A₂), arguing the descent term is
        "unrelated to a^t" — true only when every modality is scheduled.
        With measured trackers (δ ≈ ζ on small heterogeneous shards) the
        pure-bound objective degenerates to scheduling nobody; crediting
        each covered modality with its expected descent −(2η−γη²)/2·ζ_m²
        (which Theorem 2's first term only delivers for updated submodels)
        restores the paper's intended behaviour — *prioritise clients with
        unconverged (large-ζ) modalities*.  Recorded as implementation
        refinement in DESIGN.md §8 / EXPERIMENTS.md §Repro."""
        a = np.asarray(a, np.float64)
        A1, A2 = self.a1_a2(a)
        covered = 0.0
        for m in self.mods:
            has = np.array([m in cm for cm in self.client_mods], bool)
            if (has & (a > 0.5)).any():
                covered += self.zeta[m] ** 2
        c = (2 * self.eta - gamma * self.eta ** 2) / 2.0
        return (self.eta * self.rho * float(np.sqrt(A1 + A2))
                - c * covered)


# ---------------------------------------------------------------------------
# Pure jnp twins of BoundState.update_stacked — one modality's ζ/δ refresh as
# a mask-driven array program, so the tracker update fuses into the per-round
# program of the fused round engine (fl/fused_round.py).  Same semantics as
# the host version: rows with real uploads take their measured divergence,
# stale owners decay toward the fresh mean, and with no uploads at all the
# state is unchanged.
#
# The refresh is split into *partials* (ζ_new + per-client divergence norms —
# the only part that touches the gradient stack) and a shared mask/decay
# core (``_tracker_refresh``).  Two partials producers exist:
#
# * ``tracker_partials_diff`` — the direct O(J·|θ|) difference pass against a
#   pre-aggregated gradient (the historical form, kept for the host-parity
#   paths);
# * ``tracker_partials_gram`` — consumes a per-modality Gram matrix
#   G = Σ_leaves X Xᵀ (``grad_gram``, [J, J]) and the Eq. 12 weights:
#   ζ² = wᵀGw and δ_j² = G_jj − 2(Gw)_j + wᵀGw, so the fused round needs
#   NO aggregated gradient and no second reduction pass over the stack —
#   one Gram contraction yields every tracker statistic
#   (benchmarks/fusion_kernel.py measures the retired pass).
# ---------------------------------------------------------------------------
def tracker_partials_diff(stacked_g, agg_g):
    """(ζ_new, per-row ‖g_j − ḡ‖ [J]) by direct difference against the
    aggregate — one full pass over the [J, ...] gradient stack."""
    lead = jax.tree.leaves(stacked_g)[0].shape[0]
    zeta_new = jnp.sqrt(tree_sq_norm(agg_g))
    sq = sum(jnp.square(gs - ga[None]).reshape(lead, -1).sum(axis=1)
             for gs, ga in zip(jax.tree.leaves(stacked_g),
                               jax.tree.leaves(agg_g)))
    return zeta_new, jnp.sqrt(sq)


def grad_gram(stacked_g):
    """Per-modality Gram matrix of a stacked gradient pytree: [J, J] with
    G_ij = ⟨g_i, g_j⟩ summed over leaves — the single contraction pass the
    Gram-form tracker refresh needs (zero-padded rows yield zero rows, so
    cohort padding is harmless)."""
    leaves = jax.tree.leaves(stacked_g)
    lead = leaves[0].shape[0]
    return sum(jnp.matmul(x.reshape(lead, -1), x.reshape(lead, -1).T)
               for x in leaves)


def tracker_partials_gram(gram, w):
    """(ζ_new, per-row ‖g_j − ḡ‖) from the Gram matrix and aggregation
    weights, via ḡ = Σ_j w_j g_j: ζ² = wᵀGw, δ_j² = G_jj − 2(Gw)_j + wᵀGw
    (clamped at 0 against f32 cancellation)."""
    w = jnp.asarray(w, gram.dtype)
    gw = gram @ w                                               # [J]
    wgw = w @ gw
    zeta_new = jnp.sqrt(jnp.maximum(wgw, 0.0))
    sq = jnp.maximum(jnp.diagonal(gram) - 2.0 * gw + wgw, 0.0)
    return zeta_new, jnp.sqrt(sq)


def _tracker_refresh(zeta_m, delta_m, zeta_new, norms_c, mask_c, idx, has_m,
                     staleness: float):
    """Shared mask/decay core: scatter cohort-local divergence norms into the
    dense [K] δ row (``idx`` [J] duplicate-free; the dense path passes
    ``arange(K)``), decay stale owners toward the fresh mean, keep everything
    unchanged when nothing uploaded."""
    mask_c = jnp.asarray(mask_c, bool)
    has_m = jnp.asarray(has_m, bool)
    any_m = mask_c.any()
    mean_d = (norms_c * mask_c).sum() / jnp.maximum(mask_c.sum(), 1)
    decayed = staleness * delta_m + (1.0 - staleness) * mean_d
    K = delta_m.shape[0]
    uploaded = jnp.zeros(K, bool).at[idx].set(mask_c)
    norms_k = jnp.zeros(K, delta_m.dtype).at[idx].set(
        jnp.where(mask_c, norms_c, 0.0))
    delta_new = jnp.where(uploaded, norms_k,
                          jnp.where(has_m & ~uploaded, decayed, delta_m))
    return (jnp.where(any_m, zeta_new, zeta_m),
            jnp.where(any_m, delta_new, delta_m))


def tracker_update_masked(zeta_m, delta_m, stacked_g, agg_g, mask, has_m,
                          staleness: float):
    """Refresh (ζ_m, δ_{·,m}) from a stacked gradient pytree.

    zeta_m: scalar; delta_m: [K]; ``stacked_g`` leaves carry a leading client
    axis [K, ...]; ``agg_g`` is the Eq. 9 aggregate (exact zeros when ``mask``
    is empty); ``mask``/``has_m`` are bool [K] (uploaded this round / owns the
    modality).  Traced-safe: every branch of the host version becomes a
    ``jnp.where``."""
    zeta_new, norms = tracker_partials_diff(stacked_g, agg_g)
    K = delta_m.shape[0]
    return _tracker_refresh(zeta_m, delta_m, zeta_new, norms, mask,
                            jnp.arange(K), has_m, staleness)


def tracker_update_cohort(zeta_m, delta_m, cohort_g, agg_g, mask_c, idx,
                          has_m, staleness: float):
    """Cohort-gather twin of ``tracker_update_masked``: the gradient stack
    exists only for the gathered cohort ([J]-leading leaves), so per-client
    divergence norms are computed cohort-locally — O(J·|θ|), not O(K·|θ|) —
    and *scattered* into the dense [K] δ row through the duplicate-free
    cohort index vector ``idx`` [J].  ``mask_c`` bool [J] marks real uploads
    among the cohort slots (padding slots are False); ``has_m`` bool [K] is
    dense ownership.  Cohort slots appear in ascending client order with
    zeros elsewhere, so the fresh-mean reduction matches the dense path's
    summation order bit for bit."""
    zeta_new, norms_c = tracker_partials_diff(cohort_g, agg_g)
    return _tracker_refresh(zeta_m, delta_m, zeta_new, norms_c, mask_c, idx,
                            has_m, staleness)


def tracker_update_gram(zeta_m, delta_m, gram, w_c, mask_c, idx, has_m,
                        staleness: float):
    """Gram-form cohort refresh — what the fused round engine runs.  Takes
    the [J, J] Gram matrix (``grad_gram``) and the cohort's Eq. 12 weights
    ``w_c`` [J] instead of gradient stacks, so the ζ/δ refresh costs O(J²)
    on top of the single Gram contraction and the aggregated gradient is
    never materialised.  Agrees with ``tracker_update_cohort`` to f32
    reduction/cancellation tolerance (tests/test_fusion_vjp.py)."""
    zeta_new, norms_c = tracker_partials_gram(gram, w_c)
    return _tracker_refresh(zeta_m, delta_m, zeta_new, norms_c, mask_c, idx,
                            has_m, staleness)


# ---------------------------------------------------------------------------
# Batched jnp port of a1_a2 / objective — the Theorem-1 term for a whole
# antibody population A ∈ {0,1}^{P×K} as one fused array program.  Used by
# wireless.solver so the bound fuses into the same jitted JCSBA solve; the
# float64 numpy mirror lives in wireless/solver/ref.py and parity between the
# three implementations is asserted in tests/test_solver_parity.py.
# ---------------------------------------------------------------------------
def a1_a2_batched(A, zeta2, delta2, wbar, has, D):
    """A₁, A₂ of Theorem 1 for a population.

    A: [P, K] (bool or 0/1 float); snapshot arrays as from
    ``BoundState.snapshot()``.  Returns (A1 [P], A2 [P])."""
    Af = jnp.asarray(A, jnp.float32)
    part = has[None] & (Af[:, None, :] > 0.5)             # [P, M, K]
    sched = part.any(-1)                                  # m ∈ M^t   [P, M]
    A1 = ((~sched) * zeta2).sum(-1)
    wt_raw = jnp.where(part, D, 0.0)                      # w^t_{k,m} numerator
    denom = wt_raw.sum(-1, keepdims=True)
    wt = jnp.where(denom > 0, wt_raw / jnp.maximum(denom, 1e-30), 0.0)
    cover = (Af[:, None, :] * wbar).sum(-1)               # Σ a_k w̄_{k,m}
    coeff = wt + wbar - 2.0 * Af[:, None, :] * wbar
    A2_m = 2.0 * (1.0 - cover) * (coeff * delta2).sum(-1)
    A2 = jnp.maximum((sched * A2_m).sum(-1), 0.0)
    return A1, A2


def objective_batched(A, zeta2, delta2, wbar, has, D,
                      eta: float, rho: float, gamma: float = 1.0):
    """Population twin of ``BoundState.objective`` — ηρ√(A₁+A₂) minus the
    descent credit of covered modalities (see ``objective``'s docstring for
    why the credit is kept).  Returns [P]."""
    Af = jnp.asarray(A, jnp.float32)
    A1, A2 = a1_a2_batched(Af, zeta2, delta2, wbar, has, D)
    sched = (has[None] & (Af[:, None, :] > 0.5)).any(-1)
    covered = (sched * zeta2).sum(-1)
    c = (2 * eta - gamma * eta ** 2) / 2.0
    return eta * rho * jnp.sqrt(A1 + A2) - c * covered
