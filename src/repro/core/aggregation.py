"""Per-modality global aggregation — Eqs. (9)-(12) of the paper.

The global multimodal model is the stack of per-modality submodels.  In round
t only participating clients that *have* modality m contribute to submodel m;
their weights are renormalised to the participated aggregation weight
``w^t_{k,m} = D_k / sum_{i in K_m^t} D_i`` (Eq. 12).  If no participant has
modality m, the submodel is unchanged.  With full participation this equals
the unified weights ``w̄_{k,m}`` (Eq. 9-10), which makes the scheme unbiased —
property tested in tests/test_aggregation.py.
"""
from __future__ import annotations

from typing import Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


def stacked_weights(data_sizes: Sequence[int],
                    upload_mask: Mapping[str, np.ndarray]
                    ) -> Dict[str, np.ndarray]:
    """Eq. 12 weights from a contributor mask: ``upload_mask[m]`` is a bool
    [K] marking the clients contributing to submodel m.  Every other weight
    helper is a specific mask construction over this normalization."""
    D = np.asarray(data_sizes, np.float64)
    out = {}
    for m, mask in upload_mask.items():
        w = np.where(np.asarray(mask, bool), D, 0.0)
        tot = w.sum()
        out[m] = w / tot if tot > 0 else w
    return out


def unified_weights(data_sizes: Sequence[int],
                    modalities: Sequence[Sequence[str]],
                    all_modalities: Sequence[str]) -> Dict[str, np.ndarray]:
    """w̄_{k,m} over the full population K_m (Eq. 9)."""
    return stacked_weights(data_sizes, {
        m: np.array([m in mods for mods in modalities])
        for m in all_modalities})


def participated_weights(data_sizes: Sequence[int],
                         modalities: Sequence[Sequence[str]],
                         participants: Sequence[int],
                         all_modalities: Sequence[str]) -> Dict[str, np.ndarray]:
    """w^t_{k,m} over K_m^t (Eq. 12); zero row if K_m^t is empty."""
    part = np.zeros(len(data_sizes), bool)
    part[list(participants)] = True
    return stacked_weights(data_sizes, {
        m: np.array([m in mods for mods in modalities]) & part
        for m in all_modalities})


def weights_from_uploads(data_sizes: Sequence[int],
                         client_params: Sequence[Mapping[str, object]],
                         all_modalities: Sequence[str]) -> Dict[str, np.ndarray]:
    """Participated weights computed from what was *actually uploaded* —
    under modality dropout [28] a client's upload may miss a modality it
    owns; renormalising over real contributors keeps Eq. 12 a convex
    combination (tested in test_aggregation.py)."""
    return stacked_weights(data_sizes, {
        m: np.array([cp is not None and m in cp for cp in client_params])
        for m in all_modalities})


def aggregate_stacked(global_params: Mapping[str, object],
                      stacked_params: Mapping[str, object],
                      weights: Mapping[str, np.ndarray]) -> Dict[str, object]:
    """θ^t_{g,m} = Σ_k w^t_{k,m} θ^t_{k,m} over a *stacked* pytree whose
    leaves carry a leading client axis [K, ...] (the batched round engine's
    layout) — one weighted contraction per leaf instead of a Python loop
    over clients.  Zero-weight rows (non-participants, masked modalities)
    drop out of the contraction; if Σ_k w_{k,m} == 0 the global submodel m
    is returned unchanged, as in ``aggregate``."""
    new_global: Dict[str, object] = {}
    for m, g_sub in global_params.items():
        w = weights[m]
        if m not in stacked_params or w.sum() <= 0:
            new_global[m] = g_sub
            continue
        wj = jnp.asarray(w, jnp.float32)
        new_global[m] = jax.tree.map(
            lambda x: jnp.tensordot(wj, x, axes=1), stacked_params[m])
    return new_global


def aggregate_gradients_stacked(stacked_grads: Mapping[str, object],
                                weights: Mapping[str, np.ndarray]
                                ) -> Dict[str, object]:
    """Stacked twin of ``aggregate_gradients``: weighted contraction of
    [K, ...] gradient leaves; modalities with no contributor are omitted."""
    out: Dict[str, object] = {}
    for m, g in stacked_grads.items():
        w = weights[m]
        if w.sum() <= 0:
            continue
        wj = jnp.asarray(w, jnp.float32)
        out[m] = jax.tree.map(lambda x: jnp.tensordot(wj, x, axes=1), g)
    return out


# ---------------------------------------------------------------------------
# Traced twins of the stacked helpers — pure jnp, mask-driven, no host branch.
# The host helpers above branch on ``w.sum() <= 0`` in Python, which cannot
# run under jit; these express the same semantics with ``jnp.where`` so the
# whole Eq. 12 aggregation fuses into the per-round program of the fused
# round engine (fl/fused_round.py).  Equivalence with the host versions is
# covered by tests/test_fused_round.py.
# ---------------------------------------------------------------------------
def upload_masks_traced(ok, has: Mapping[str, object],
                        drop: Optional[Mapping[str, object]] = None
                        ) -> Dict[str, object]:
    """The Eq. 12 contributor masks as a traced program: client k contributes
    to submodel m iff it participated (``ok`` — scheduled ∧ no transmission
    failure), owns the modality (``has[m]``) and did not drop it this round
    (``drop[m]``, the modality-dropout baseline's [28] per-round mask; None ⇒
    no policy drops).  A dropped modality is therefore excluded from both the
    masked local update and the Eq. 12 renormalisation — exactly the
    sequential path's "absent from the upload" semantics
    (``weights_from_uploads``); property-tested in
    tests/test_fused_properties.py."""
    ok = jnp.asarray(ok, bool)
    out = {}
    for m, h in has.items():
        u = ok & jnp.asarray(h, bool)
        if drop is not None and m in drop:
            u = u & ~jnp.asarray(drop[m], bool)
        out[m] = u
    return out


def stacked_weights_traced(D, upload_mask: Mapping[str, object]
                           ) -> Dict[str, object]:
    """Eq. 12 weights from traced contributor masks: ``upload_mask[m]`` is a
    bool [K] (traced or concrete); a contributor-free modality keeps its
    all-zero row, exactly like ``stacked_weights``."""
    D = jnp.asarray(D, jnp.float32)
    out = {}
    for m, mask in upload_mask.items():
        w = jnp.where(jnp.asarray(mask, bool), D, 0.0)
        tot = w.sum()
        out[m] = jnp.where(tot > 0, w / jnp.maximum(tot, 1e-30), w)
    return out


def aggregate_stacked_traced(global_params: Mapping[str, object],
                             stacked_params: Mapping[str, object],
                             weights: Mapping[str, object]
                             ) -> Dict[str, object]:
    """``aggregate_stacked`` with traced weights: Σ_k w_{k,m} == 0 keeps the
    global submodel unchanged via ``jnp.where`` instead of a Python branch."""
    new_global: Dict[str, object] = {}
    for m, g_sub in global_params.items():
        if m not in stacked_params:
            new_global[m] = g_sub
            continue
        w = jnp.asarray(weights[m], jnp.float32)
        has_contrib = w.sum() > 0
        new_global[m] = jax.tree.map(
            lambda old, x: jnp.where(has_contrib,
                                     jnp.tensordot(w, x, axes=1), old),
            g_sub, stacked_params[m])
    return new_global


def aggregate_gradients_stacked_traced(stacked_grads: Mapping[str, object],
                                       weights: Mapping[str, object]
                                       ) -> Dict[str, object]:
    """Traced twin of ``aggregate_gradients_stacked``.  A contributor-free
    modality yields an exact-zero aggregate (all weights zero) instead of
    being omitted — downstream consumers gate on the upload mask."""
    return {m: jax.tree.map(
        lambda x: jnp.tensordot(jnp.asarray(weights[m], jnp.float32), x,
                                axes=1), g)
        for m, g in stacked_grads.items()}


# ---------------------------------------------------------------------------
# Cohort-gather path (O(J), not O(K)).  The fused round engine gathers only
# the scheduled cohort's rows (policies emit a static-size, duplicate-free
# cohort index vector — wireless.policies.cohort_indices), so Eq. 12 runs as
# the same traced helpers above over [J]-leading stacks: every contributor is
# in the cohort by construction, so the renormalisation over J equals the
# dense renormalisation over K.  What *is* new is the inverse map — cohort-
# local results scattered back to dense [K] rows via a segment-sum over the
# cohort indices (duplicate-free ⇒ a pure scatter) — used for the dense
# per-round weight records and the ζ/δ tracker refresh
# (convergence.tracker_update_cohort).  Equivalence with the dense masked
# path is property-tested in tests/test_cohort_gather.py.
# ---------------------------------------------------------------------------
def scatter_cohort_rows(vals_c, idx, K: int):
    """Segment-sum cohort-local values back to dense client rows.

    ``vals_c`` [J, ...] holds one row per cohort slot, ``idx`` [J] int32 the
    cohort's client indices (duplicate-free; padding slots carry exact-zero
    rows or are masked upstream).  Returns [K, ...] with zeros at non-cohort
    clients."""
    return jax.ops.segment_sum(vals_c, idx, num_segments=K)


def cohort_weights_dense(weights_c: Mapping[str, object], idx, K: int
                         ) -> Dict[str, object]:
    """Dense [K] Eq. 12 weight rows from cohort-local weights [J] — the
    segment-sum scatter per modality (padding slots have zero weight, so the
    scatter is exact)."""
    return {m: scatter_cohort_rows(jnp.asarray(w, jnp.float32), idx, K)
            for m, w in weights_c.items()}


def aggregate(global_params: Mapping[str, object],
              client_params: List[Mapping[str, object]],
              weights: Mapping[str, np.ndarray]) -> Dict[str, object]:
    """θ^t_{g,m} = Σ_k w^t_{k,m} θ^t_{k,m} (Eq. 12), per modality.

    ``client_params[k]`` holds only the modalities client k trained; absent
    clients/modalities simply get zero weight.  If Σ_k w_{k,m} == 0 the global
    submodel m is returned unchanged.
    """
    new_global: Dict[str, object] = {}
    for m, g_sub in global_params.items():
        w = weights[m]
        if w.sum() <= 0:
            new_global[m] = g_sub
            continue
        acc = jax.tree.map(jnp.zeros_like, g_sub)
        for k, cp in enumerate(client_params):
            if cp is None or m not in cp or w[k] == 0:
                continue
            acc = jax.tree.map(lambda a, x: a + w[k] * x, acc, cp[m])
        new_global[m] = acc
    return new_global


def aggregate_gradients(grads_by_client: List[Mapping[str, object]],
                        weights: Mapping[str, np.ndarray]) -> Dict[str, object]:
    """∇H(θ_{g,m}) = Σ_k w_{k,m} ∇H_k(θ_{g,m}) (Eq. 9) — used by the ζ/δ
    trackers in ``convergence.py``."""
    out: Dict[str, object] = {}
    mods = set()
    for g in grads_by_client:
        if g:
            mods.update(g.keys())
    for m in mods:
        w = weights[m]
        acc = None
        for k, g in enumerate(grads_by_client):
            if g is None or m not in g or w[k] == 0:
                continue
            term = jax.tree.map(lambda x: w[k] * x, g[m])
            acc = term if acc is None else jax.tree.map(jnp.add, acc, term)
        if acc is not None:
            out[m] = acc
    return out
